//! Scenario stress grid: encoded vs uncoded across the built-in
//! adversarial scenario library (time-varying degradation,
//! rack-correlated slowdowns, crash/rejoin, heterogeneous hardware) —
//! the "arbitrary sequences of delay patterns" axis of the paper's
//! sample-path guarantees, as a sweep instead of a single delay model.
//!
//!     cargo bench --bench scenario_grid

use coded_opt::bench::banner;
use coded_opt::config::{Algorithm, Scheme};
use coded_opt::control::KPolicy;
use coded_opt::scenario::{run_grid, summary_table, GridSpec, Scenario};

fn main() -> anyhow::Result<()> {
    banner(
        "Scenario grid",
        "Scheme × Solver × Scenario sweep on the deterministic SimCluster",
    );
    let spec = GridSpec {
        schemes: vec![Scheme::Uncoded, Scheme::Replication, Scheme::Hadamard, Scheme::Haar],
        algorithms: Algorithm::synchronous().to_vec(),
        scenarios: Scenario::builtin_names()
            .iter()
            .map(|n| Scenario::builtin(n).unwrap())
            .collect(),
        n: 512,
        p: 64,
        m: 8,
        k: 6,
        beta: 2.0,
        iters: 60,
        seed: 42,
        lambda: 0.05,
        policy: KPolicy::Static,
    };
    println!(
        "{} cells: n={} p={} m={} k={} β={} iters={}\n",
        spec.cells(),
        spec.n,
        spec.p,
        spec.m,
        spec.k,
        spec.beta,
        spec.iters
    );
    let cells = run_grid(&spec)?;
    summary_table(&cells).print();
    println!(
        "\nPaper shape: the encoded schemes keep converging under every scenario \
         (crash windows are erasures the redundancy absorbs), while uncoded \
         fixed-k is biased whenever the same blocks keep dropping."
    );
    Ok(())
}
