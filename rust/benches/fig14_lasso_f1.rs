//! Figure 14: LASSO sparsity-recovery F1 over time under the trimodal
//! delay mixture — uncoded k=m, uncoded k<m, replication, Steiner k<m,
//! each one [`Experiment`](coded_opt::driver::Experiment) running the
//! [`Prox`] solver.
//!
//!     cargo bench --bench fig14_lasso_f1

use coded_opt::bench::banner;
use coded_opt::config::Scheme;
use coded_opt::data::synth::sparse_recovery;
use coded_opt::delay::MixtureDelay;
use coded_opt::driver::{Experiment, Problem, Prox};
use coded_opt::metrics::{f1_support, Trace};
use coded_opt::objectives::LassoProblem;

const SECS_PER_UNIT: f64 = 2e-4;

fn main() -> anyhow::Result<()> {
    banner("Figure 14", "LASSO support-recovery F1 vs time, trimodal delays");
    // paper: 130000×100000, 7695-sparse, σ=40, λ=0.6, m=128, k∈{80,128}
    // — scaled preserving n/p, sparsity fraction, and k/m.
    let (n, p, nnz) = (1040usize, 800usize, 62usize);
    let (m, k_partial) = (16usize, 10usize);
    let lambda = 0.05;
    let (x, y, w_star) = sparse_recovery(n, p, nnz, 0.5, 31);
    let prob = LassoProblem::new(x.clone(), y.clone(), lambda);
    let step = prob.default_step();
    let iters = 300;

    let runs: Vec<(&str, Scheme, usize)> = vec![
        ("uncoded k=m", Scheme::Uncoded, m),
        ("uncoded k<m", Scheme::Uncoded, k_partial),
        ("replication", Scheme::Replication, k_partial),
        ("steiner k<m", Scheme::Steiner, k_partial),
    ];
    let mut traces: Vec<Trace> = Vec::new();
    for (label, scheme, k) in runs {
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(scheme)
            .workers(m)
            .wait_for(k)
            .redundancy(2.0)
            .seed(7)
            .delay(|m| Box::new(MixtureDelay::paper_trimodal(m, 23)))
            .timing(SECS_PER_UNIT, 1e-3)
            .label(label)
            .eval(|w| {
                let (_, _, f1) = f1_support(&w_star, w, 1e-2);
                (prob.objective(w), f1)
            })
            .run(Prox::with_step(step).lambda(lambda).iters(iters))?;
        traces.push(out.trace);
    }

    let t_max = traces.iter().map(|t| t.total_time()).fold(0.0, f64::max);
    println!("\nF1 score at time t:");
    print!("{:<10}", "time(s)");
    for t in &traces {
        print!(" {:>14}", t.label);
    }
    println!();
    for i in 1..=10 {
        let cp = t_max * i as f64 / 10.0;
        print!("{:<10.0}", cp);
        for t in &traces {
            print!(" {:>14.3}", t.test_metric_at_time(cp));
        }
        println!();
    }
    println!("\nfinal F1 / total time:");
    for t in &traces {
        println!("  {:<14} F1 {:.3} in {:.0}s", t.label, t.final_test_metric(), t.total_time());
    }
    println!("\nPaper shape (Fig. 14): steiner k<m reaches uncoded-k=m recovery quality");
    println!("at a fraction of the wall time; uncoded k<m loses F1 (dropped data);");
    println!("waiting for all (k=m) pays the straggler tail every iteration.");
    Ok(())
}
