//! Figure 13: fraction of (asynchronous) updates performed by each node
//! under the same power-law background load — the async counterpart of
//! Figure 12, driven by the same
//! [`Experiment`](coded_opt::driver::Experiment) API with the
//! [`AsyncBcd`] solver. The horizontal reference is the uniform 1/m
//! line.
//!
//!     cargo bench --bench fig13_participation_async

use coded_opt::bench::banner;
use coded_opt::data::rcv1like;
use coded_opt::delay::BackgroundTasksDelay;
use coded_opt::driver::{AsyncBcd, Experiment, Problem};
use coded_opt::objectives::LogisticProblem;

fn main() -> anyhow::Result<()> {
    banner("Figure 13", "per-node update fraction, async BCD (same load as Fig. 12)");
    let (docs, feats, nnz) = (500usize, 192usize, 10usize);
    let m = 16usize;
    let ds = rcv1like::generate(docs, feats, nnz, 0.05, 77);
    let x = ds.train.to_dense();
    let prob = LogisticProblem::new(ds.train.clone(), 1e-4);
    let step = 1.0 / prob.smoothness() / 4.0;
    let bg = BackgroundTasksDelay::new(m, 1.5, 50, 0.05, 31);
    let tasks: Vec<usize> = bg.task_counts().to_vec();
    let out = Experiment::new(Problem::logistic(&x))
        .workers(m)
        .delay_model(Box::new(bg))
        .timing(1e-4, 1e-3)
        .label("async")
        // 300 iterations × k=16-equivalent budget
        .run(AsyncBcd::with_step(step).lambda(1e-4).updates(4800).record_every(1200))?;
    let part = out.participation;
    let total: f64 = (0..m).map(|i| part.fraction(i)).sum();
    println!("\nnode  bg-tasks  update fraction   (uniform line = {:.4})", 1.0 / m as f64);
    for i in 0..m {
        let frac = part.fraction(i) / total;
        let bar = "#".repeat((200.0 * frac).round() as usize);
        println!("{i:>4}  {:>8}  {frac:>7.4} |{bar}", tasks[i]);
    }
    println!("\nimbalance (cv) = {:.3}", part.imbalance());
    println!("\nPaper shape (Fig. 13): stark non-uniformity — fast nodes perform orders");
    println!("of magnitude more updates; loaded nodes contribute rare, stale updates,");
    println!("which is precisely what degrades async convergence in Figs. 10–11.");
    Ok(())
}
