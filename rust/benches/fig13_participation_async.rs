//! Figure 13: fraction of (asynchronous) updates performed by each node
//! under the same power-law background load — the async counterpart of
//! Figure 12. The horizontal reference is the uniform 1/m line.
//!
//!     cargo bench --bench fig13_participation_async

use coded_opt::bench::banner;
use coded_opt::coordinator::asynchronous::{run_async_bcd, AsyncBcdConfig};
use coded_opt::data::rcv1like;
use coded_opt::delay::BackgroundTasksDelay;
use coded_opt::encoding::partition_bounds;
use coded_opt::objectives::LogisticProblem;

fn main() -> anyhow::Result<()> {
    banner("Figure 13", "per-node update fraction, async BCD (same load as Fig. 12)");
    let (docs, feats, nnz) = (500usize, 192usize, 10usize);
    let m = 16usize;
    let ds = rcv1like::generate(docs, feats, nnz, 0.05, 77);
    let x = ds.train.to_dense();
    let n_train = ds.train.rows();
    let prob = LogisticProblem::new(ds.train.clone(), 1e-4);
    let step = 1.0 / prob.smoothness() / 4.0;
    let bounds = partition_bounds(feats, m);
    let blocks: Vec<coded_opt::linalg::Mat> = bounds
        .windows(2)
        .map(|w| x.select_cols(&(w[0]..w[1]).collect::<Vec<_>>()))
        .collect();
    let grad_phi = |u: &[f64]| -> Vec<f64> {
        let n = u.len() as f64;
        u.iter().map(|&ui| -coded_opt::objectives::logistic::sigmoid(-ui) / n).collect()
    };
    let bg = BackgroundTasksDelay::new(m, 1.5, 50, 0.05, 31);
    let tasks: Vec<usize> = bg.task_counts().to_vec();
    let mut delay = bg;
    let cfg = AsyncBcdConfig {
        step,
        lambda: 1e-4,
        updates: 4800, // 300 iterations × k=16-equivalent budget
        secs_per_unit: 1e-4,
        record_every: 1200,
    };
    let eval = |_: &[Vec<f64>]| (0.0, 0.0);
    let (_, _, part) = run_async_bcd(&blocks, &grad_phi, n_train, &cfg, &mut delay, "async", &eval);
    let total: f64 = (0..m).map(|i| part.fraction(i)).sum();
    println!("\nnode  bg-tasks  update fraction   (uniform line = {:.4})", 1.0 / m as f64);
    for i in 0..m {
        let frac = part.fraction(i) / total;
        let bar = "#".repeat((200.0 * frac).round() as usize);
        println!("{i:>4}  {:>8}  {frac:>7.4} |{bar}", tasks[i]);
    }
    println!("\nimbalance (cv) = {:.3}", part.imbalance());
    println!("\nPaper shape (Fig. 13): stark non-uniformity — fast nodes perform orders");
    println!("of magnitude more updates; loaded nodes contribute rare, stale updates,");
    println!("which is precisely what degrades async convergence in Figs. 10–11.");
    Ok(())
}
