//! Figure 10: logistic regression (encoded BCD, model parallelism) —
//! train/test error over TIME under the bimodal delay mixture
//! (q=0.5: N(0.5s, 0.2²) + N(20s, 5²)), k/m = 0.5, β = 2.
//! Schemes: Steiner, Haar, uncoded, replication(-equivalent), async —
//! every run through the same [`Experiment`](coded_opt::driver::Experiment).
//!
//!     cargo bench --bench fig10_logistic_bimodal

use coded_opt::bench::banner;
use coded_opt::config::Scheme;
use coded_opt::coordinator::bcd::replication_equivalent;
use coded_opt::data::rcv1like;
use coded_opt::delay::{MinOfR, MixtureDelay};
use coded_opt::driver::{AsyncBcd, Bcd, Experiment, Problem};
use coded_opt::metrics::Trace;
use coded_opt::objectives::LogisticProblem;

const SECS_PER_UNIT: f64 = 1e-4;

fn main() -> anyhow::Result<()> {
    banner("Figure 10", "logistic BCD, bimodal stragglers: error vs time");
    // paper: m=128, k=64, β=2 on rcv1 — scaled: m=16, k=8
    let (docs, feats, nnz) = (700usize, 256usize, 12usize);
    let (m, k) = (16usize, 8usize);
    let ds = rcv1like::generate(docs, feats, nnz, 0.05, 77);
    let x = ds.train.to_dense();
    let prob = LogisticProblem::new(ds.train.clone(), 1e-4);
    let step = 1.0 / prob.smoothness() / 4.0;
    let iters = 400;

    let mut traces: Vec<Trace> = Vec::new();

    // ---- encoded / uncoded BCD. "uncoded k=m" is the paper's main
    // baseline: it waits for every straggler (≈20 s nodes) each round.
    let sync_runs: Vec<(&str, Scheme, usize, f64, usize)> = vec![
        ("steiner k<m", Scheme::Steiner, k, 2.0, iters),
        ("haar k<m", Scheme::Haar, k, 2.0, iters),
        ("uncoded k<m", Scheme::Uncoded, k, 1.0, iters),
        // far fewer rounds fit in the same wall budget at k=m
        ("uncoded k=m", Scheme::Uncoded, m, 1.0, iters),
    ];
    for (label, scheme, k_run, beta, it) in sync_runs {
        let out = Experiment::new(Problem::logistic(&x))
            .scheme(scheme)
            .workers(m)
            .wait_for(k_run)
            .redundancy(beta)
            .seed(13)
            .delay(|m| Box::new(MixtureDelay::paper_bimodal(m, 29)))
            .timing(SECS_PER_UNIT, 1e-3)
            .label(label)
            .eval(|w| (prob.objective(w), prob.error_rate(w, &ds.test)))
            .run(Bcd::with_step(step).lambda(1e-4).iters(it))?;
        traces.push(out.trace);
    }

    // ---- replication-equivalent: P logical workers, fastest-of-2 delays
    {
        let (p_logical, k_logical) = replication_equivalent(m, 2, k);
        let out = Experiment::new(Problem::logistic(&x))
            .scheme(Scheme::Uncoded)
            .workers(p_logical)
            .wait_for(k_logical)
            .redundancy(1.0)
            .seed(13)
            .delay(move |p| Box::new(MinOfR::new(MixtureDelay::paper_bimodal(2 * p, 29), 2)))
            .timing(SECS_PER_UNIT, 1e-3)
            .label("replication")
            .eval(|w| (prob.objective(w), prob.error_rate(w, &ds.test)))
            .run(Bcd::with_step(step).lambda(1e-4).iters(iters))?;
        traces.push(out.trace);
    }

    // ---- async baseline, same wall budget
    {
        let budget = traces.iter().map(|t| t.total_time()).fold(0.0, f64::max);
        // async applies ~1 update per mean-delay per worker; cap generously
        let out = Experiment::new(Problem::logistic(&x))
            .workers(m)
            .delay(|m| Box::new(MixtureDelay::paper_bimodal(m, 29)))
            .timing(SECS_PER_UNIT, 1e-3)
            .label("async")
            .eval(|w| (prob.objective(w), prob.error_rate(w, &ds.test)))
            .run(AsyncBcd::with_step(step).lambda(1e-4).updates(40_000).record_every(200))?;
        // truncate to the synchronized runs' wall budget for fairness
        let mut trace = out.trace;
        trace.records.retain(|r| r.time <= budget);
        traces.push(trace);
    }

    // ---- print error-vs-time series (axis spans the k<m runs; the
    // k=m run is far slower — its state is read at the same checkpoints)
    let t_max = traces
        .iter()
        .filter(|t| t.label != "uncoded k=m")
        .map(|t| t.total_time())
        .fold(0.0, f64::max);
    let checkpoints: Vec<f64> = (1..=8).map(|i| t_max * i as f64 / 8.0).collect();
    println!("\ntrain objective at time t:");
    print!("{:<10}", "time(s)");
    for t in &traces {
        print!(" {:>12}", t.label);
    }
    println!();
    for &cp in &checkpoints {
        print!("{:<10.0}", cp);
        for t in &traces {
            print!(" {:>12.4}", t.objective_at_time(cp));
        }
        println!();
    }
    println!("\ntest error at time t:");
    print!("{:<10}", "time(s)");
    for t in &traces {
        print!(" {:>12}", t.label);
    }
    println!();
    for &cp in &checkpoints {
        print!("{:<10.0}", cp);
        for t in &traces {
            print!(" {:>12.4}", t.test_metric_at_time(cp));
        }
        println!();
    }
    println!("\nfinal state per run:");
    for t in &traces {
        println!(
            "  {:<14} obj {:.4}  test err {:.3}  total sim time {:.0}s",
            t.label,
            t.final_objective(),
            t.final_test_metric(),
            t.total_time()
        );
    }
    println!("\nPaper shape (Fig. 10): waiting for all (uncoded k=m) pays the ~20 s");
    println!("straggler tail every round — k<m schemes do ~10× more rounds in the");
    println!("same wall time; the encoded ones keep full-data fidelity while doing so.");
    Ok(())
}
