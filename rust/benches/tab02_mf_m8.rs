//! Table 2: full MovieLens-style MF results, m = 8 nodes,
//! k ∈ {1, 4, 6}: train/test RMSE and runtime per scheme, plus the
//! full-batch (k = m) reference row.
//!
//!     cargo bench --bench tab02_mf_m8

use coded_opt::bench::banner;
use coded_opt::config::Scheme;
use coded_opt::coordinator::mf::{mf_experiment, MfExperimentCfg};
use coded_opt::metrics::TableWriter;

fn main() -> anyhow::Result<()> {
    banner("Table 2", "MF full results, m = 8 (train RMSE / test RMSE / runtime)");
    let schemes = [
        Scheme::Uncoded,
        Scheme::Replication,
        Scheme::Gaussian,
        Scheme::Paley,
        Scheme::Hadamard,
    ];
    let base = MfExperimentCfg {
        users: 80,
        movies: 240,
        dim: 8,
        ratings_per_user: 40,
        lambda: 2.0,
        epochs: 3,
        m: 8,
        k: 8,
        scheme: Scheme::Uncoded,
        threshold: 40,
        seed: 7,
    };
    for k in [1usize, 4, 6] {
        let mut table =
            TableWriter::new(&["", "uncoded", "replication", "gaussian", "paley", "hadamard"]);
        let mut train_row = vec!["train RMSE".to_string()];
        let mut test_row = vec!["test RMSE".to_string()];
        let mut time_row = vec!["runtime".to_string()];
        for scheme in schemes {
            let (train, test, time) =
                mf_experiment(&MfExperimentCfg { k, scheme, ..base });
            train_row.push(format!("{train:.3}"));
            test_row.push(format!("{test:.3}"));
            time_row.push(format!("{time:.1}s"));
        }
        println!("\n--- m = 8, k = {k} ---");
        table.row(&train_row);
        table.row(&test_row);
        table.row(&time_row);
        table.print();
    }
    // full-batch reference (paper's caption: uncoded k = m)
    let (train, test, time) = mf_experiment(&base);
    println!(
        "\nfull-batch reference (uncoded, k=m=8): train {train:.3} / test {test:.3} / {time:.1}s"
    );
    println!("\nPaper shape (Table 2): at k=1 coded schemes hold test RMSE close to the");
    println!("k=m reference while uncoded/replication degrade; runtimes grow with k.");
    Ok(())
}
