//! End-to-end integration over the thread cluster: real OS-thread
//! workers, wall-clock interrupts, all algorithms through the
//! [`Experiment`](coded_opt::driver::Experiment) driver on
//! [`Engine::Threads`], coded vs baselines. (The examples/ directory
//! holds the human-facing drivers; these are the CI-grade assertions.)

use coded_opt::config::Scheme;
use coded_opt::data::synth::{gaussian_linear, sparse_recovery};
use coded_opt::delay::{AdversarialDelay, ConstantDelay, MixtureDelay};
use coded_opt::driver::{Engine, Experiment, Gd, Lbfgs, Problem, Prox};
use coded_opt::metrics::f1_support;
use coded_opt::objectives::{LassoProblem, QuadObjective, RidgeProblem};

#[test]
fn threaded_gd_with_real_interrupts() {
    let (x, y, _) = gaussian_linear(64, 8, 0.3, 3);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f_star = prob.objective(&prob.solve_exact());
    // 2 workers are 30 ms stragglers; wait-for-2 of 4.
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Hadamard)
        .workers(4)
        .wait_for(2)
        .redundancy(2.0)
        .seed(3)
        .engine(Engine::Threads { delay_scale: 1.0 })
        .delay(|m| Box::new(AdversarialDelay::new(m, vec![1, 3], 0.03)))
        .label("threads")
        .eval(|w| (prob.objective(w), 0.0))
        .run(Gd::with_step(1.0 / prob.smoothness()).lambda(0.05).iters(150))
        .unwrap();
    let sub = (out.trace.final_objective() - f_star) / f_star;
    assert!(sub < 0.3, "subopt {sub}");
    // stragglers were interrupted, not waited for
    assert!(out.participation.fraction(1) < 0.2);
    assert!(out.participation.fraction(3) < 0.2);
}

#[test]
fn threaded_lbfgs_bimodal_delays() {
    let (x, y, _) = gaussian_linear(96, 12, 0.3, 5);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f_star = prob.objective(&prob.solve_exact());
    // paper's bimodal delays scaled to milliseconds
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Haar)
        .workers(8)
        .wait_for(6)
        .redundancy(2.0)
        .seed(5)
        .engine(Engine::Threads { delay_scale: 1e-3 })
        .delay(|m| Box::new(MixtureDelay::paper_bimodal(m, 7)))
        .label("threads-lbfgs")
        .eval(|w| (prob.objective(w), 0.0))
        .run(Lbfgs::new().iters(40).lambda(0.05))
        .unwrap();
    let sub = (out.trace.final_objective() - f_star) / f_star;
    assert!(sub < 0.05, "subopt {sub}");
}

#[test]
fn threaded_prox_lasso_recovery() {
    let (x, y, w_star) = sparse_recovery(96, 32, 5, 0.1, 7);
    let prob = LassoProblem::new(x.clone(), y.clone(), 0.08);
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Steiner)
        .workers(6)
        .wait_for(4)
        .redundancy(2.0)
        .seed(7)
        .engine(Engine::Threads { delay_scale: 1.0 })
        .delay(|m| Box::new(AdversarialDelay::new(m, vec![0], 0.02)))
        .label("threads-prox")
        .eval(|w| (prob.objective(w), 0.0))
        .run(Prox::with_step(prob.default_step()).lambda(0.08).iters(150))
        .unwrap();
    let (_, _, f1) = f1_support(&w_star, &out.w, 1e-2);
    assert!(f1 > 0.7, "f1 {f1}");
}

#[test]
fn sim_and_thread_clusters_agree_on_final_iterate() {
    // Same problem, same A_t pattern (adversarial fixed stragglers make
    // the active sets deterministic): the two engines must produce the
    // same optimization path — only the engine line differs between the
    // two experiments.
    let (x, y, _) = gaussian_linear(48, 6, 0.2, 9);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let solver = Gd::with_step(1.0 / prob.smoothness()).lambda(0.05).iters(40);
    let base = || {
        Experiment::new(Problem::least_squares(&x, &y))
            .scheme(Scheme::Hadamard)
            .workers(4)
            .wait_for(3)
            .redundancy(2.0)
            .seed(9)
    };
    let out_sim = base()
        .delay(|m| Box::new(AdversarialDelay::new(m, vec![2], 1e6)))
        .label("sim")
        .run(solver)
        .unwrap();
    let out_thr = base()
        .engine(Engine::Threads { delay_scale: 1.0 })
        .delay(|m| Box::new(AdversarialDelay::new(m, vec![2], 0.02)))
        .label("thr")
        .run(solver)
        .unwrap();
    let err = coded_opt::testutil::rel_err(&out_thr.w, &out_sim.w);
    assert!(err < 1e-9, "engines diverged: rel err {err}");
}

#[test]
fn thread_cluster_clock_reflects_waits() {
    let (x, y, _) = gaussian_linear(32, 4, 0.2, 11);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.0);
    // constant 10 ms delay on everyone
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Uncoded)
        .workers(4)
        .wait_for(4)
        .redundancy(1.0)
        .seed(11)
        .engine(Engine::Threads { delay_scale: 1.0 })
        .delay(|m| Box::new(ConstantDelay::new(m, 0.01)))
        .label("clock")
        .eval(|w| (prob.objective(w), 0.0))
        .run(Gd::with_step(1e-3).iters(5))
        .unwrap();
    // 5 rounds × ≥10 ms each
    assert!(out.trace.total_time() >= 0.05, "clock {}", out.trace.total_time());
}
