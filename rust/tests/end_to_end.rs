//! End-to-end integration over the thread cluster: real OS-thread
//! workers, wall-clock interrupts, all four algorithms, coded vs
//! baselines. (The examples/ directory holds the human-facing drivers;
//! these are the CI-grade assertions.)

use coded_opt::cluster::ThreadCluster;
use coded_opt::config::Scheme;
use coded_opt::coordinator::{build_data_parallel, GdConfig, LbfgsConfig, ProxConfig};
use coded_opt::data::synth::{gaussian_linear, sparse_recovery};
use coded_opt::delay::{AdversarialDelay, MixtureDelay};
use coded_opt::metrics::f1_support;
use coded_opt::objectives::{LassoProblem, QuadObjective, RidgeProblem};

#[test]
fn threaded_gd_with_real_interrupts() {
    let (x, y, _) = gaussian_linear(64, 8, 0.3, 3);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f_star = prob.objective(&prob.solve_exact());
    let dp = build_data_parallel(&x, &y, Scheme::Hadamard, 4, 2.0, 3).unwrap();
    let asm = dp.assembler.clone();
    // 2 workers are 30 ms stragglers; wait-for-2 of 4.
    let delay = AdversarialDelay::new(4, vec![1, 3], 0.03);
    let mut cluster = ThreadCluster::new(dp.workers, Box::new(delay));
    let cfg = GdConfig { k: 2, step: 1.0 / prob.smoothness(), iters: 150, lambda: 0.05, w0: None };
    let out = coded_opt::coordinator::run_gd(&mut cluster, &asm, &cfg, "threads", &|w| {
        (prob.objective(w), 0.0)
    });
    let sub = (out.trace.final_objective() - f_star) / f_star;
    assert!(sub < 0.3, "subopt {sub}");
    // stragglers were interrupted, not waited for
    assert!(out.participation.fraction(1) < 0.2);
    assert!(out.participation.fraction(3) < 0.2);
}

#[test]
fn threaded_lbfgs_bimodal_delays() {
    let (x, y, _) = gaussian_linear(96, 12, 0.3, 5);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f_star = prob.objective(&prob.solve_exact());
    let dp = build_data_parallel(&x, &y, Scheme::Haar, 8, 2.0, 5).unwrap();
    let asm = dp.assembler.clone();
    // paper's bimodal delays scaled to milliseconds
    let delay = MixtureDelay::paper_bimodal(8, 7);
    let mut cluster = ThreadCluster::new(dp.workers, Box::new(delay)).with_delay_scale(1e-3);
    let cfg = LbfgsConfig { k: 6, iters: 40, lambda: 0.05, memory: 10, rho: 0.9, w0: None };
    let out = coded_opt::coordinator::run_lbfgs(&mut cluster, &asm, &cfg, "threads-lbfgs", &|w| {
        (prob.objective(w), 0.0)
    });
    let sub = (out.trace.final_objective() - f_star) / f_star;
    assert!(sub < 0.05, "subopt {sub}");
}

#[test]
fn threaded_prox_lasso_recovery() {
    let (x, y, w_star) = sparse_recovery(96, 32, 5, 0.1, 7);
    let prob = LassoProblem::new(x.clone(), y.clone(), 0.08);
    let dp = build_data_parallel(&x, &y, Scheme::Steiner, 6, 2.0, 7).unwrap();
    let asm = dp.assembler.clone();
    let delay = AdversarialDelay::new(6, vec![0], 0.02);
    let mut cluster = ThreadCluster::new(dp.workers, Box::new(delay));
    let cfg = ProxConfig { k: 4, step: prob.default_step(), iters: 150, lambda: 0.08, w0: None };
    let out = coded_opt::coordinator::run_prox(&mut cluster, &asm, &cfg, "threads-prox", &|w| {
        (prob.objective(w), 0.0)
    });
    let (_, _, f1) = f1_support(&w_star, &out.w, 1e-2);
    assert!(f1 > 0.7, "f1 {f1}");
}

#[test]
fn sim_and_thread_clusters_agree_on_final_iterate() {
    // Same problem, same A_t pattern (adversarial fixed stragglers make
    // the active sets deterministic): the two engines must produce the
    // same optimization path.
    let (x, y, _) = gaussian_linear(48, 6, 0.2, 9);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let cfg = GdConfig { k: 3, step: 1.0 / prob.smoothness(), iters: 40, lambda: 0.05, w0: None };
    // sim
    let dp1 = build_data_parallel(&x, &y, Scheme::Hadamard, 4, 2.0, 9).unwrap();
    let asm1 = dp1.assembler.clone();
    let mut sim = coded_opt::cluster::SimCluster::new(
        dp1.workers,
        Box::new(AdversarialDelay::new(4, vec![2], 1e6)),
    );
    let out_sim = coded_opt::coordinator::run_gd(&mut sim, &asm1, &cfg, "sim", &|w| {
        (prob.objective(w), 0.0)
    });
    // threads
    let dp2 = build_data_parallel(&x, &y, Scheme::Hadamard, 4, 2.0, 9).unwrap();
    let asm2 = dp2.assembler.clone();
    let mut thr = ThreadCluster::new(dp2.workers, Box::new(AdversarialDelay::new(4, vec![2], 0.02)));
    let out_thr = coded_opt::coordinator::run_gd(&mut thr, &asm2, &cfg, "thr", &|w| {
        (prob.objective(w), 0.0)
    });
    let err = coded_opt::testutil::rel_err(&out_thr.w, &out_sim.w);
    assert!(err < 1e-9, "engines diverged: rel err {err}");
}

#[test]
fn thread_cluster_clock_reflects_waits() {
    let (x, y, _) = gaussian_linear(32, 4, 0.2, 11);
    let dp = build_data_parallel(&x, &y, Scheme::Uncoded, 4, 1.0, 11).unwrap();
    let asm = dp.assembler.clone();
    // constant 10 ms delay on everyone
    let delay = coded_opt::delay::ConstantDelay::new(4, 0.01);
    let mut cluster = ThreadCluster::new(dp.workers, Box::new(delay));
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.0);
    let cfg = GdConfig { k: 4, step: 1e-3, iters: 5, lambda: 0.0, w0: None };
    let out = coded_opt::coordinator::run_gd(&mut cluster, &asm, &cfg, "clock", &|w| {
        (prob.objective(w), 0.0)
    });
    // 5 rounds × ≥10 ms each
    assert!(out.trace.total_time() >= 0.05, "clock {}", out.trace.total_time());
    drop(cluster); // clean shutdown joins workers
}
