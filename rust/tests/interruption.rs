//! Interruption semantics for `ThreadCluster`: a worker aborted
//! mid-round must never contribute a stale payload to a later round's
//! aggregation — the regression guard for the abort/iter sentinel logic
//! in `cluster/threads.rs` (the paper's footnote 1: the master's
//! interrupt signal makes the worker drop, not delay, its result).

// This suite pins bit-exact float values on purpose; exact equality
// is the contract under test, not an accident (the workspace denies
// clippy::float_cmp for library code).
#![allow(clippy::float_cmp)]

use coded_opt::cluster::{Gather, Task, ThreadCluster, WorkerNode};
use coded_opt::config::Scheme;
use coded_opt::coordinator::{build_data_parallel, KIND_GRADIENT};
use coded_opt::data::synth::gaussian_linear;
use coded_opt::delay::TraceDelay;
use coded_opt::objectives::{QuadObjective, RidgeProblem};

/// Echoes `(id, iter)` so the master can audit exactly which round each
/// payload was computed for.
struct TagWorker {
    id: usize,
}

impl WorkerNode for TagWorker {
    fn process(&mut self, task: &Task) -> Vec<f64> {
        vec![self.id as f64, task.iter as f64]
    }
}

fn tag_cluster(m: usize, delay: TraceDelay) -> ThreadCluster {
    let workers: Vec<Box<dyn WorkerNode>> =
        (0..m).map(|id| Box::new(TagWorker { id }) as Box<dyn WorkerNode>).collect();
    ThreadCluster::new(workers, Box::new(delay))
}

fn task(iter: usize) -> Task {
    Task { iter, kind: 0, payload: vec![], aux: vec![] }
}

#[test]
fn aborted_worker_never_leaks_a_stale_payload() {
    // Round 0: worker 2 sleeps 40 ms, k=2 of 3 ⇒ it is aborted
    // mid-sleep. Rounds 1..6 are full gathers with zero delay, racing
    // the woken worker's (dropped) round-0 task against fresh ones.
    let m = 3;
    let mut rows = vec![vec![0.0, 0.0, 0.04]];
    rows.extend(std::iter::repeat(vec![0.0; m]).take(6));
    let mut c = tag_cluster(m, TraceDelay::new(rows));
    let r0 = c.round(2, &mut |_| task(0));
    assert_eq!(r0.active_set(), vec![0, 1]);
    assert_eq!(r0.interrupted, vec![2]);
    for t in 1..7 {
        let rr = c.round(m, &mut |_| task(t));
        assert_eq!(rr.responses.len(), m, "round {t}");
        let mut seen = vec![false; m];
        for r in &rr.responses {
            assert_eq!(
                r.payload[1], t as f64,
                "round {t}: worker {} delivered a payload computed for round {}",
                r.worker, r.payload[1]
            );
            assert!(!seen[r.worker], "round {t}: duplicate response from {}", r.worker);
            seen[r.worker] = true;
        }
    }
}

#[test]
fn repeated_interruptions_never_cross_rounds() {
    // A different worker stalls every round (rotating straggler); every
    // gathered payload must still carry its own round's tag.
    let m = 4;
    let rounds = 12;
    let rows: Vec<Vec<f64>> = (0..rounds)
        .map(|t| (0..m).map(|w| if w == t % m { 0.02 } else { 0.0 }).collect())
        .collect();
    let mut c = tag_cluster(m, TraceDelay::new(rows));
    for t in 0..rounds {
        let rr = c.round(m - 1, &mut |_| task(t));
        assert_eq!(rr.responses.len(), m - 1);
        for r in &rr.responses {
            assert_eq!(r.payload[1], t as f64, "round {t}, worker {}", r.worker);
        }
        assert!(!rr.interrupted.is_empty());
    }
}

#[test]
fn stale_gradients_never_reach_the_assembler() {
    // End-to-end version against the real `QuadWorker`/`GradAssembler`
    // path: round 0 aborts a straggler that was handed iterate w0; round
    // 1 is a full gather on a DIFFERENT iterate w1. If the sentinel
    // logic ever let the stale (w0-based, or duplicated) payload through,
    // the assembled full-gather gradient could not equal the exact
    // gradient at w1.
    let (x, y, _) = gaussian_linear(48, 6, 0.3, 17);
    let m = 4;
    let dp = build_data_parallel(&x, &y, Scheme::Hadamard, m, 2.0, 17).unwrap();
    let asm = dp.assembler.clone();
    let delay = TraceDelay::new(vec![
        vec![0.03, 0.0, 0.0, 0.0],
        vec![0.0; 4],
        vec![0.0; 4],
    ]);
    let mut cluster = ThreadCluster::new(dp.workers, Box::new(delay));
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.0);

    let w0: Vec<f64> = (0..6).map(|i| 0.3 * i as f64 - 0.7).collect();
    let r0 = cluster.round(3, &mut |_| Task {
        iter: 0,
        kind: KIND_GRADIENT,
        payload: w0.clone(),
        aux: vec![],
    });
    assert_eq!(r0.interrupted, vec![0], "worker 0 must be the round-0 straggler");

    for (t, shift) in [(1usize, 0.11), (2usize, -0.23)] {
        let wt: Vec<f64> = w0.iter().map(|v| v + shift).collect();
        let rr = cluster.round(4, &mut |_| Task {
            iter: t,
            kind: KIND_GRADIENT,
            payload: wt.clone(),
            aux: vec![],
        });
        assert_eq!(rr.responses.len(), 4, "round {t}");
        let g = asm.assemble(&rr.responses);
        let g_exact = prob.gradient(&wt);
        let err = coded_opt::testutil::rel_err(&g, &g_exact);
        assert!(
            err < 1e-9,
            "round {t}: assembled gradient off by {err} — a stale or duplicate \
             payload leaked into the aggregation"
        );
    }
}
