//! Property-based invariants over the coordinator substrate (the
//! mini-proptest framework from `coded_opt::testutil`): routing,
//! batching, gather semantics, assembly, and worker state machines
//! under randomized inputs.

use coded_opt::cluster::{Gather, SimCluster, Task, WorkerNode};
use coded_opt::config::Scheme;
use coded_opt::coordinator::bcd::BcdWorker;
use coded_opt::coordinator::{KIND_BCD_STEP, KIND_GRADIENT};
use coded_opt::delay::TraceDelay;
use coded_opt::driver::{Experiment, Problem};
use coded_opt::encoding::{EncodingOp, ReplicationMap};
use coded_opt::linalg::Mat;
use coded_opt::testutil::PropRunner;

struct Echo(usize);
impl WorkerNode for Echo {
    fn process(&mut self, task: &Task) -> Vec<f64> {
        vec![self.0 as f64, task.iter as f64]
    }
}

/// Gather invariant: for any m, k, delay pattern — exactly k responses,
/// A_t ⊎ interrupted = [m], arrivals non-decreasing, elapsed = k-th
/// arrival.
#[test]
fn prop_gather_partitions_workers() {
    PropRunner::new("gather_partitions", 0xA11).cases(60).run(
        |g| {
            let m = g.usize_in(1, 24);
            let k = g.usize_in(1, m);
            let rounds = g.usize_in(1, 5);
            let delays: Vec<Vec<f64>> = (0..rounds.max(1))
                .map(|_| (0..m).map(|_| g.f64_in(0.0, 10.0)).collect())
                .collect();
            (m, k, rounds, delays)
        },
        |(m, k, rounds, delays)| {
            let workers: Vec<Box<dyn WorkerNode>> =
                (0..*m).map(|i| Box::new(Echo(i)) as Box<dyn WorkerNode>).collect();
            let delay = TraceDelay::new(delays.clone());
            let mut cluster = SimCluster::new(workers, Box::new(delay));
            for t in 0..*rounds {
                let rr = cluster.round(*k, &mut |_| Task {
                    iter: t,
                    kind: KIND_GRADIENT,
                    payload: vec![],
                    aux: vec![],
                });
                if rr.responses.len() != *k {
                    return Err(format!("got {} responses, wanted {k}", rr.responses.len()));
                }
                let mut all = rr.active_set();
                all.extend(rr.interrupted.iter());
                all.sort_unstable();
                if all != (0..*m).collect::<Vec<_>>() {
                    return Err("A_t ⊎ A_tᶜ ≠ [m]".into());
                }
                for pair in rr.responses.windows(2) {
                    if pair[1].arrival < pair[0].arrival {
                        return Err("arrivals out of order".into());
                    }
                }
                let last = rr.responses.last().unwrap().arrival;
                if (rr.elapsed - last).abs() > 1e-12 {
                    return Err("elapsed != k-th arrival".into());
                }
            }
            Ok(())
        },
    );
}

/// Assembly invariant: with k = m (full gather) on any tight-frame
/// scheme, the assembled gradient equals the exact (1/n)Xᵀ(Xw−y),
/// regardless of response ARRIVAL ORDER.
#[test]
fn prop_full_gather_assembly_order_invariant() {
    PropRunner::new("assembly_exact", 0xA12).cases(25).run(
        |g| {
            let n = 8 * g.usize_in(2, 6);
            let p = g.usize_in(2, 8);
            let m = [2usize, 4, 8][g.usize_in(0, 2)];
            let scheme = [Scheme::Hadamard, Scheme::Haar, Scheme::Uncoded][g.usize_in(0, 2)];
            let seed = g.usize_in(0, 1_000_000) as u64;
            let w: Vec<f64> = (0..p).map(|_| g.f64_in(-1.0, 1.0)).collect();
            // random per-worker delays → random arrival order
            let delays: Vec<f64> = (0..m).map(|_| g.f64_in(0.0, 5.0)).collect();
            (n, p, m, scheme, seed, w, delays)
        },
        |(n, p, m, scheme, seed, w, delays)| {
            let (x, y, _) = coded_opt::data::synth::gaussian_linear(*n, *p, 0.3, *seed);
            let mut parts = Experiment::new(Problem::least_squares(&x, &y))
                .scheme(*scheme)
                .workers(*m)
                .redundancy(2.0)
                .seed(*seed)
                .delay(|_| Box::new(TraceDelay::new(vec![delays.clone()])))
                .assemble_data_parallel()
                .unwrap();
            let asm = parts.assembler.clone();
            let cluster = &mut parts.cluster;
            let rr = cluster.round(*m, &mut |_| Task {
                iter: 0,
                kind: KIND_GRADIENT,
                payload: w.clone(),
                aux: vec![],
            });
            let g_est = asm.assemble(&rr.responses);
            let resid = coded_opt::linalg::sub(&x.matvec(w), &y);
            let mut g_exact = x.matvec_t(&resid);
            coded_opt::linalg::scale(1.0 / *n as f64, &mut g_exact);
            let err = coded_opt::testutil::rel_err(&g_est, &g_exact);
            if err > 1e-8 {
                return Err(format!("rel err {err}"));
            }
            Ok(())
        },
    );
}

/// Replication routing invariant: resolve() returns distinct partitions,
/// each mapped worker actually holds that partition, respects arrival
/// order, and coverage is monotone in the responder set.
#[test]
fn prop_replication_resolve() {
    PropRunner::new("replication_resolve", 0xA13).cases(80).run(
        |g| {
            let r = [1usize, 2, 4][g.usize_in(0, 2)];
            let parts = g.usize_in(1, 8);
            let m = r * parts;
            let k = g.usize_in(1, m);
            let order = g.subset(m, k);
            (m, r, order)
        },
        |(m, r, order)| {
            let map = ReplicationMap::new(*m, *r);
            let resolved = map.resolve(order);
            let mut seen = std::collections::BTreeSet::new();
            for &(p, w) in &resolved {
                if map.partition_of(w) != p {
                    return Err(format!("worker {w} does not hold partition {p}"));
                }
                if !seen.insert(p) {
                    return Err(format!("partition {p} duplicated"));
                }
                if !order.contains(&w) {
                    return Err(format!("worker {w} never responded"));
                }
            }
            // monotonicity: adding responders can only add partitions
            let partial = map.coverage(&order[..order.len() / 2]);
            if partial > resolved.len() {
                return Err("coverage not monotone".into());
            }
            Ok(())
        },
    );
}

/// Encoding invariant: every construction at every feasible size is an
/// (approximate) tight frame — ‖(1/β)·SᵀS − I‖_F/√n small — and
/// block shapes tile the full matrix.
#[test]
fn prop_encodings_are_tight_frames() {
    PropRunner::new("tight_frames", 0xA14).cases(30).run(
        |g| {
            let scheme = [Scheme::Hadamard, Scheme::Haar, Scheme::Steiner, Scheme::Paley]
                [g.usize_in(0, 3)];
            let n = g.usize_in(6, 40);
            let m = g.usize_in(1, 8);
            let seed = g.usize_in(0, 1_000_000) as u64;
            (scheme, n, m, seed)
        },
        |(scheme, n, m, seed)| {
            let enc = EncodingOp::build(*scheme, *n, *m, 2.0, *seed)
                .map_err(|e| format!("build failed: {e}"))?;
            if enc.workers() != *m {
                return Err("wrong worker count".into());
            }
            let rows: usize = (0..enc.workers()).map(|i| enc.block_rows(i)).sum();
            if rows != enc.total_rows() {
                return Err("blocks don't tile".into());
            }
            let subset: Vec<usize> = (0..*m).collect();
            let s = enc.stack(&subset);
            let mut g_mat = s.gram();
            g_mat.scale_inplace(1.0 / enc.beta);
            let nn = enc.n;
            let mut off = 0.0;
            for i in 0..nn {
                for j in 0..nn {
                    let expect = if i == j { 1.0 } else { 0.0 };
                    let d = g_mat[(i, j)] - expect;
                    off += d * d;
                }
            }
            let fro = (off / nn as f64).sqrt();
            if fro > 1e-6 {
                return Err(format!("{scheme:?} n={nn}: ‖G−I‖/√n = {fro}"));
            }
            Ok(())
        },
    );
}

/// BCD worker state machine: under a random accept/reject sequence the
/// worker's v must equal a reference replay that applies exactly the
/// accepted pending steps.
#[test]
fn prop_bcd_accept_state_machine() {
    PropRunner::new("bcd_state", 0xA15).cases(40).run(
        |g| {
            let b = g.usize_in(1, 5);
            let rounds = g.usize_in(1, 12);
            let accept: Vec<bool> = (0..rounds).map(|_| g.bool_with(0.6)).collect();
            let z: Vec<f64> = (0..3).map(|_| g.f64_in(-1.0, 1.0)).collect();
            (b, rounds, accept, z)
        },
        |(b, rounds, accept, z)| {
            // A = ones(3, b) so gradients are analytic; φ = identity/1.
            let a = Mat::from_fn(3, *b, |_, _| 1.0);
            let mut worker =
                BcdWorker::new(a.clone(), 0.1, 0.0, Box::new(|u: &[f64]| u.to_vec()));
            // reference state
            let mut v_ref = vec![0.0; *b];
            let mut pending_ref: Option<(usize, Vec<f64>)> = None;
            let mut last_accept: i64 = -1;
            for t in 0..*rounds {
                let task = Task {
                    iter: t,
                    kind: KIND_BCD_STEP,
                    payload: z.clone(),
                    aux: vec![last_accept as f64],
                };
                let out = worker.process(&task);
                // reference replay
                if let Some((pr, pd)) = &pending_ref {
                    if *pr as i64 == last_accept {
                        for i in 0..*b {
                            v_ref[i] += pd[i];
                        }
                    }
                }
                let xw = {
                    let mut xw = a.matvec(&v_ref);
                    coded_opt::linalg::axpy(1.0, z, &mut xw);
                    xw
                };
                let grad = a.matvec_t(&xw);
                pending_ref = Some((t, grad.iter().map(|g| -0.1 * g).collect()));
                // compare returned v part
                let v_got = &out[3..];
                for i in 0..*b {
                    if (v_got[i] - v_ref[i]).abs() > 1e-12 {
                        return Err(format!("t={t}: v[{i}] {} vs ref {}", v_got[i], v_ref[i]));
                    }
                }
                // master's accept decision for this round
                if accept[t] {
                    last_accept = t as i64;
                }
            }
            Ok(())
        },
    );
}

/// Config validation invariant: any config the validator accepts has
/// 1 ≤ k ≤ m and β ≥ 1; any it rejects violates one of them.
#[test]
fn prop_config_validation() {
    PropRunner::new("config_validate", 0xA16).cases(100).run(
        |g| {
            let mut cfg = coded_opt::config::ExperimentConfig::default();
            cfg.workers = g.usize_in(0, 40);
            cfg.k = g.usize_in(0, 50);
            cfg.beta = g.f64_in(0.0, 4.0);
            cfg
        },
        |cfg| {
            let ok = cfg.validate().is_ok();
            let legal = cfg.workers >= 1 && cfg.k >= 1 && cfg.k <= cfg.workers && cfg.beta >= 1.0;
            if ok != legal {
                return Err(format!(
                    "validate()={ok} but legality={legal} (m={}, k={}, β={})",
                    cfg.workers, cfg.k, cfg.beta
                ));
            }
            Ok(())
        },
    );
}
