//! Golden-trace regression suite: a fixed Scheme × Solver × Scenario
//! matrix runs on the deterministic `SimCluster` with pinned seeds and
//! its traces are compared bit-for-bit against checked-in fixtures under
//! `tests/fixtures/golden/`.
//!
//! - Missing fixtures are *blessed* (written) on first run, so a fresh
//!   checkout self-seeds; commit the generated files to pin behavior.
//! - Set `BLESS=1` to regenerate all fixtures after an intentional
//!   change to coordinator/driver numerics.
//! - Set `GOLDEN_STRICT=1` to FAIL on any blessed fixture instead:
//!   auto-blessing silently passes when no fixtures exist at all, so a
//!   comparison run that would bless anything is a run that compared
//!   nothing. CI sets it on every golden pass after the first (the
//!   cross-process re-run and both thread-invariance runs), which turns
//!   "fixtures quietly regenerated" into a hard failure.
//! - `scenario_grid_is_bit_deterministic` holds unconditionally: the
//!   same grid run twice in-process must serialize identically, which is
//!   the determinism claim of the paper's sample-path guarantees made
//!   executable.
//! - The `adaptive__*` fixtures pin the wait-for-k controller
//!   ([`coded_opt::control`]) on the same machinery: their
//!   [`canonical_trace`] serialization additionally carries every
//!   per-round k decision and arrival time, so a drifting controller
//!   heuristic fails the byte compare even when the iterates survive.

// This suite pins bit-exact float values on purpose; exact equality
// is the contract under test, not an accident (the workspace denies
// clippy::float_cmp for library code).
#![allow(clippy::float_cmp)]

use std::fs;
use std::path::PathBuf;

use coded_opt::config::{Algorithm, Scheme};
use coded_opt::control::{erasure_floor, KPolicy};
use coded_opt::data::synth::gaussian_linear;
use coded_opt::driver::{Experiment, Gd, Problem, RunOutput};
use coded_opt::objectives::RidgeProblem;
use coded_opt::scenario::{canonical_trace, run_grid, DelayRecorder, GridCell, GridSpec, Scenario};

/// The pinned matrix: 2 schemes × 3 solvers × 4 scenarios = 24 cells,
/// including crash/rejoin and rack-correlated adversaries.
fn golden_spec() -> GridSpec {
    GridSpec {
        schemes: vec![Scheme::Hadamard, Scheme::Gaussian],
        algorithms: vec![Algorithm::Gd, Algorithm::Lbfgs, Algorithm::ProxGradient],
        scenarios: vec![
            Scenario::builtin("warmup-degrade").unwrap(),
            Scenario::builtin("rack-correlated").unwrap(),
            Scenario::builtin("crash-rejoin").unwrap(),
            Scenario::builtin("hetero-speed").unwrap(),
        ],
        n: 64,
        p: 8,
        m: 8,
        k: 6,
        beta: 2.0,
        iters: 12,
        seed: 1234,
        lambda: 0.05,
        policy: KPolicy::Static,
    }
}

/// The controller matrix: 2 schemes × 2 scenarios under the default
/// adaptive policy, Gd only. Small on purpose — each cell's fixture
/// pins the full k-decision sequence, so two adversaries (correlated
/// stragglers, crash/rejoin) per scheme already cover both directions
/// the controller can move k.
fn adaptive_spec() -> GridSpec {
    GridSpec {
        schemes: vec![Scheme::Hadamard, Scheme::Gaussian],
        algorithms: vec![Algorithm::Gd],
        scenarios: vec![
            Scenario::builtin("rack-correlated").unwrap(),
            Scenario::builtin("crash-rejoin").unwrap(),
        ],
        n: 64,
        p: 8,
        m: 8,
        k: 6,
        beta: 2.0,
        iters: 12,
        seed: 1234,
        lambda: 0.05,
        policy: KPolicy::Adaptive(Default::default()),
    }
}

fn fixtures_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden")
}

/// Compare `cells` against fixtures named `{prefix}{stem}.trace`,
/// blessing missing ones (or all of them under `BLESS=1`) unless
/// `GOLDEN_STRICT=1` forbids it. Shared by the static and adaptive
/// fixture tests so both matrices get identical bless/strict semantics.
fn compare_or_bless(cells: &[GridCell], prefix: &str) {
    let dir = fixtures_dir();
    fs::create_dir_all(&dir).expect("create fixtures dir");
    let bless = std::env::var("BLESS").is_ok();
    let strict = std::env::var("GOLDEN_STRICT").is_ok_and(|v| v != "0" && !v.is_empty());
    assert!(
        !(bless && strict),
        "BLESS and GOLDEN_STRICT are mutually exclusive: strict mode exists to \
         prove no fixture was (re)generated"
    );
    let mut blessed = 0usize;
    for cell in cells {
        let path = dir.join(format!("{prefix}{}.trace", cell.stem()));
        let got = canonical_trace(cell);
        if bless || !path.exists() {
            assert!(
                !strict,
                "GOLDEN_STRICT=1: fixture {} is missing — this run would bless it \
                 and compare nothing. A strict pass needs the full committed (or \
                 previously blessed) fixture set.",
                path.display()
            );
            fs::write(&path, &got).expect("write fixture");
            blessed += 1;
            continue;
        }
        let want = fs::read_to_string(&path).expect("read fixture");
        assert_eq!(
            got, want,
            "golden trace drift for {prefix}{} — coordinator/driver numerics changed. \
             If intentional, regenerate fixtures with `BLESS=1 cargo test golden`.",
            cell.stem()
        );
    }
    if blessed > 0 {
        eprintln!(
            "golden_traces: blessed {blessed}/{} fixtures in {} \
             (first run or BLESS=1); commit them to pin behavior",
            cells.len(),
            dir.display()
        );
    }
}

#[test]
fn scenario_grid_is_bit_deterministic() {
    let spec = golden_spec();
    let a = run_grid(&spec).expect("grid run 1");
    let b = run_grid(&spec).expect("grid run 2");
    assert_eq!(a.len(), spec.cells());
    assert_eq!(a.len(), b.len());
    for (ca, cb) in a.iter().zip(&b) {
        assert_eq!(
            canonical_trace(ca),
            canonical_trace(cb),
            "non-deterministic trace for cell {}",
            ca.stem()
        );
    }
}

#[test]
fn golden_traces_match_fixtures() {
    let cells = run_grid(&golden_spec()).expect("grid run");
    compare_or_bless(&cells, "");
}

#[test]
fn crash_rejoin_cells_really_erase_and_readmit() {
    // Structural check behind the golden bits: in the crash-rejoin
    // scenario the crashed pair participates in no round inside the
    // window but is readmitted after it.
    let mut spec = golden_spec();
    spec.schemes = vec![Scheme::Hadamard];
    spec.algorithms = vec![Algorithm::Gd];
    spec.scenarios = vec![Scenario::builtin("crash-rejoin").unwrap()];
    spec.iters = 25;
    let cells = run_grid(&spec).unwrap();
    let out = &cells[0].out;
    // every round still gathered exactly k
    assert!(out.trace.records.iter().all(|r| r.k_used == spec.k));
    // the crash window [5, 15) spans 10 of 25 rounds: a crashed worker
    // can participate in at most 15 rounds
    let fractions = out.participation.fractions();
    let crashed_like =
        fractions.iter().filter(|&&f| f <= 15.0 / 25.0 + 1e-9).count();
    assert!(
        crashed_like >= 2,
        "expected ≥ 2 workers capped by the crash window, fractions={fractions:?}"
    );
    // but nobody is erased forever (rejoin works; k=6 of 8 leaves head
    // room for everyone to appear at least once over 25 rounds)
    assert!(
        out.trace.total_time().is_finite(),
        "crash must never poison the virtual clock"
    );
}

#[test]
fn adaptive_grid_is_bit_deterministic() {
    let spec = adaptive_spec();
    let a = run_grid(&spec).expect("adaptive grid run 1");
    let b = run_grid(&spec).expect("adaptive grid run 2");
    assert_eq!(a.len(), spec.cells());
    assert_eq!(a.len(), b.len());
    for (ca, cb) in a.iter().zip(&b) {
        assert_eq!(
            canonical_trace(ca),
            canonical_trace(cb),
            "non-deterministic adaptive trace for cell {}",
            ca.stem()
        );
    }
}

#[test]
fn adaptive_golden_traces_match_fixtures() {
    let cells = run_grid(&adaptive_spec()).expect("adaptive grid run");
    for cell in &cells {
        // The fixtures must actually pin controller decisions: every
        // cell is controller-steered and carries its round log.
        assert_eq!(cell.out.controller, "adaptive", "cell {}: not steered", cell.stem());
        assert!(!cell.out.rounds.is_empty(), "cell {}: no rounds recorded", cell.stem());
    }
    compare_or_bless(&cells, "adaptive__");
}

/// Bit-level equality of two controller-steered runs: every trace
/// record, every per-round k decision with its arrival times, and the
/// final iterate compared as raw `f64` bits — no tolerance anywhere.
fn assert_runs_bit_identical(a: &RunOutput, b: &RunOutput, ctx: &str) {
    assert_eq!(a.controller, b.controller, "{ctx}: controller name");
    assert_eq!(a.trace.records.len(), b.trace.records.len(), "{ctx}: trace lengths");
    for (i, (ra, rb)) in a.trace.records.iter().zip(&b.trace.records).enumerate() {
        assert_eq!(ra.iter, rb.iter, "{ctx}: record {i}: iter");
        assert_eq!(ra.k_used, rb.k_used, "{ctx}: record {i}: k_used");
        assert_eq!(ra.time.to_bits(), rb.time.to_bits(), "{ctx}: record {i}: time");
        assert_eq!(
            ra.objective.to_bits(),
            rb.objective.to_bits(),
            "{ctx}: record {i}: objective"
        );
    }
    assert_eq!(a.rounds.len(), b.rounds.len(), "{ctx}: round counts");
    for (i, (ra, rb)) in a.rounds.iter().zip(&b.rounds).enumerate() {
        assert_eq!(ra.round, rb.round, "{ctx}: round {i}: index");
        assert_eq!(ra.k_requested, rb.k_requested, "{ctx}: round {i}: k_requested");
        assert_eq!(ra.k_effective, rb.k_effective, "{ctx}: round {i}: k_effective");
        assert_eq!(ra.live, rb.live, "{ctx}: round {i}: live");
        assert_eq!(ra.elapsed.to_bits(), rb.elapsed.to_bits(), "{ctx}: round {i}: elapsed");
        let arrivals_a: Vec<u64> = ra.arrivals.iter().map(|v| v.to_bits()).collect();
        let arrivals_b: Vec<u64> = rb.arrivals.iter().map(|v| v.to_bits()).collect();
        assert_eq!(arrivals_a, arrivals_b, "{ctx}: round {i}: arrivals");
    }
    assert_eq!(a.w.len(), b.w.len(), "{ctx}: iterate lengths");
    for (j, (p, q)) in a.w.iter().zip(&b.w).enumerate() {
        assert_eq!(p.to_bits(), q.to_bits(), "{ctx}: w[{j}]: {p} vs {q}");
    }
}

#[test]
fn adaptive_tape_record_replay_is_bit_identical() {
    // The controller contract's replay clause, end to end: decisions
    // derive only from recorded arrivals, so an adaptive run taped under
    // the live rack-correlated delay model and replayed from that tape
    // must reproduce every k decision and every trace float bit-for-bit
    // (rack-correlated crashes nobody, so the tape has no holes).
    let (x, y, _) = gaussian_linear(64, 8, 0.5, 77);
    let ridge = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let step = 1.0 / ridge.smoothness();
    let inner = Scenario::builtin("rack-correlated")
        .expect("builtin scenario")
        .build_delay(8, 77)
        .expect("build delay");
    let (rec, tape) = DelayRecorder::new(inner);
    let recorded = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Hadamard)
        .workers(8)
        .wait_for(6)
        .redundancy(2.0)
        .seed(77)
        .controller(KPolicy::Adaptive(Default::default()))
        .delay_model(Box::new(rec))
        .run(Gd::with_step(step).lambda(0.05).iters(12))
        .expect("recording run");
    assert_eq!(recorded.controller, "adaptive");
    assert!(!recorded.rounds.is_empty(), "recording run logged no rounds");
    assert!(!tape.is_empty(), "recording run sampled no delays");
    let sc = Scenario::new("replay").replay(tape.snapshot());
    let replayed = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Hadamard)
        .workers(8)
        .wait_for(6)
        .redundancy(2.0)
        .seed(77)
        .controller(KPolicy::Adaptive(Default::default()))
        .scenario(&sc)
        .run(Gd::with_step(step).lambda(0.05).iters(12))
        .expect("replay run");
    assert_runs_bit_identical(&recorded, &replayed, "record vs replay");
}

#[test]
fn adaptive_k_bounded_under_crash_rejoin() {
    // Hard bounds of the controller contract, checked under the one
    // adversary that actually moves `live`: the requested k never drops
    // below the erasure floor ceil(m/β) or exceeds m, and the delivered
    // k never exceeds the live worker count.
    let mut spec = adaptive_spec();
    spec.schemes = vec![Scheme::Hadamard];
    spec.scenarios = vec![Scenario::builtin("crash-rejoin").unwrap()];
    spec.iters = 25;
    let floor = erasure_floor(spec.m, spec.beta);
    let cells = run_grid(&spec).unwrap();
    let out = &cells[0].out;
    assert!(!out.rounds.is_empty(), "adaptive crash-rejoin run logged no rounds");
    for r in &out.rounds {
        assert!(
            (floor..=spec.m).contains(&r.k_requested),
            "round {}: k_requested {} outside [{floor}, {}]",
            r.round,
            r.k_requested,
            spec.m
        );
        assert!(
            r.k_effective <= r.live,
            "round {}: k_effective {} exceeds live {}",
            r.round,
            r.k_effective,
            r.live
        );
        assert!(
            r.k_effective >= 1 && r.k_effective <= r.k_requested,
            "round {}: k_effective {} outside [1, k_requested={}]",
            r.round,
            r.k_effective,
            r.k_requested
        );
        assert_eq!(
            r.arrivals.len(),
            r.k_effective,
            "round {}: arrival log does not match delivered k",
            r.round
        );
    }
    // The crash window really bites, so the live-clamp path of the
    // bounds is exercised, not just vacuously true.
    assert!(
        out.rounds.iter().any(|r| r.live < spec.m),
        "crash-rejoin never reduced the live worker count"
    );
}
