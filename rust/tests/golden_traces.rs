//! Golden-trace regression suite: a fixed Scheme × Solver × Scenario
//! matrix runs on the deterministic `SimCluster` with pinned seeds and
//! its traces are compared bit-for-bit against checked-in fixtures under
//! `tests/fixtures/golden/`.
//!
//! - Missing fixtures are *blessed* (written) on first run, so a fresh
//!   checkout self-seeds; commit the generated files to pin behavior.
//! - Set `BLESS=1` to regenerate all fixtures after an intentional
//!   change to coordinator/driver numerics.
//! - Set `GOLDEN_STRICT=1` to FAIL on any blessed fixture instead:
//!   auto-blessing silently passes when no fixtures exist at all, so a
//!   comparison run that would bless anything is a run that compared
//!   nothing. CI sets it on every golden pass after the first (the
//!   cross-process re-run and both thread-invariance runs), which turns
//!   "fixtures quietly regenerated" into a hard failure.
//! - `scenario_grid_is_bit_deterministic` holds unconditionally: the
//!   same grid run twice in-process must serialize identically, which is
//!   the determinism claim of the paper's sample-path guarantees made
//!   executable.

// This suite pins bit-exact float values on purpose; exact equality
// is the contract under test, not an accident (the workspace denies
// clippy::float_cmp for library code).
#![allow(clippy::float_cmp)]

use std::fs;
use std::path::PathBuf;

use coded_opt::config::{Algorithm, Scheme};
use coded_opt::scenario::{canonical_trace, run_grid, GridSpec, Scenario};

/// The pinned matrix: 2 schemes × 3 solvers × 4 scenarios = 24 cells,
/// including crash/rejoin and rack-correlated adversaries.
fn golden_spec() -> GridSpec {
    GridSpec {
        schemes: vec![Scheme::Hadamard, Scheme::Gaussian],
        algorithms: vec![Algorithm::Gd, Algorithm::Lbfgs, Algorithm::ProxGradient],
        scenarios: vec![
            Scenario::builtin("warmup-degrade").unwrap(),
            Scenario::builtin("rack-correlated").unwrap(),
            Scenario::builtin("crash-rejoin").unwrap(),
            Scenario::builtin("hetero-speed").unwrap(),
        ],
        n: 64,
        p: 8,
        m: 8,
        k: 6,
        beta: 2.0,
        iters: 12,
        seed: 1234,
        lambda: 0.05,
    }
}

fn fixtures_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden")
}

#[test]
fn scenario_grid_is_bit_deterministic() {
    let spec = golden_spec();
    let a = run_grid(&spec).expect("grid run 1");
    let b = run_grid(&spec).expect("grid run 2");
    assert_eq!(a.len(), spec.cells());
    assert_eq!(a.len(), b.len());
    for (ca, cb) in a.iter().zip(&b) {
        assert_eq!(
            canonical_trace(ca),
            canonical_trace(cb),
            "non-deterministic trace for cell {}",
            ca.stem()
        );
    }
}

#[test]
fn golden_traces_match_fixtures() {
    let spec = golden_spec();
    let cells = run_grid(&spec).expect("grid run");
    let dir = fixtures_dir();
    fs::create_dir_all(&dir).expect("create fixtures dir");
    let bless = std::env::var("BLESS").is_ok();
    let strict = std::env::var("GOLDEN_STRICT").is_ok_and(|v| v != "0" && !v.is_empty());
    assert!(
        !(bless && strict),
        "BLESS and GOLDEN_STRICT are mutually exclusive: strict mode exists to \
         prove no fixture was (re)generated"
    );
    let mut blessed = 0usize;
    for cell in &cells {
        let path = dir.join(format!("{}.trace", cell.stem()));
        let got = canonical_trace(cell);
        if bless || !path.exists() {
            assert!(
                !strict,
                "GOLDEN_STRICT=1: fixture {} is missing — this run would bless it \
                 and compare nothing. A strict pass needs the full committed (or \
                 previously blessed) fixture set.",
                path.display()
            );
            fs::write(&path, &got).expect("write fixture");
            blessed += 1;
            continue;
        }
        let want = fs::read_to_string(&path).expect("read fixture");
        assert_eq!(
            got, want,
            "golden trace drift for {} — coordinator/driver numerics changed. \
             If intentional, regenerate fixtures with `BLESS=1 cargo test golden`.",
            cell.stem()
        );
    }
    if blessed > 0 {
        eprintln!(
            "golden_traces: blessed {blessed}/{} fixtures in {} \
             (first run or BLESS=1); commit them to pin behavior",
            cells.len(),
            dir.display()
        );
    }
}

#[test]
fn crash_rejoin_cells_really_erase_and_readmit() {
    // Structural check behind the golden bits: in the crash-rejoin
    // scenario the crashed pair participates in no round inside the
    // window but is readmitted after it.
    let mut spec = golden_spec();
    spec.schemes = vec![Scheme::Hadamard];
    spec.algorithms = vec![Algorithm::Gd];
    spec.scenarios = vec![Scenario::builtin("crash-rejoin").unwrap()];
    spec.iters = 25;
    let cells = run_grid(&spec).unwrap();
    let out = &cells[0].out;
    // every round still gathered exactly k
    assert!(out.trace.records.iter().all(|r| r.k_used == spec.k));
    // the crash window [5, 15) spans 10 of 25 rounds: a crashed worker
    // can participate in at most 15 rounds
    let fractions = out.participation.fractions();
    let crashed_like =
        fractions.iter().filter(|&&f| f <= 15.0 / 25.0 + 1e-9).count();
    assert!(
        crashed_like >= 2,
        "expected ≥ 2 workers capped by the crash window, fractions={fractions:?}"
    );
    // but nobody is erased forever (rejoin works; k=6 of 8 leaves head
    // room for everyone to appear at least once over 25 rounds)
    assert!(
        out.trace.total_time().is_finite(),
        "crash must never poison the virtual clock"
    );
}
