//! Driver smoke matrix: every encoding [`Scheme`] × every solver
//! through the [`Experiment`](coded_opt::driver::Experiment) API.
//!
//! The legacy `run_*` shims (and their bit-equivalence tests) are gone:
//! `Experiment` is the sole entry point, and the golden-trace suite
//! (`rust/tests/golden_traces.rs`) is what pins driver numerics across
//! refactors.

// This suite pins bit-exact float values on purpose; exact equality
// is the contract under test, not an accident (the workspace denies
// clippy::float_cmp for library code).
#![allow(clippy::float_cmp)]

use coded_opt::config::Scheme;
use coded_opt::data::synth::{gaussian_linear, sparse_recovery};
use coded_opt::driver::{AsyncBcd, AsyncGd, Bcd, Experiment, Gd, Lbfgs, Problem, Prox};
use coded_opt::objectives::{LassoProblem, QuadObjective, RidgeProblem};

/// Dimensions every scheme construction accepts (Replication needs r|m;
/// Paley/Steiner round to feasible internal sizes).
const N: usize = 64;
const P: usize = 8;
const M: usize = 4;

fn all_schemes() -> &'static [Scheme] {
    Scheme::all()
}

// ---------------------------------------------------------------- matrix

#[test]
fn smoke_matrix_gd_all_schemes() {
    let (x, y, _) = gaussian_linear(N, P, 0.3, 7);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f0 = prob.objective(&[0.0; P]);
    for &scheme in all_schemes() {
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(scheme)
            .workers(M)
            .wait_for(M)
            .redundancy(2.0)
            .seed(7)
            .label(scheme.name())
            .eval(|w| (prob.objective(w), 0.0))
            .run(Gd::with_step(0.5 / prob.smoothness()).lambda(0.05).iters(30))
            .unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        assert_eq!(out.trace.len(), 30, "{scheme:?}");
        // full gather + conservative step ⇒ monotone descent on the
        // ORIGINAL objective. Gaussian is only approximately tight
        // (ETFs/Hadamard/Haar are exact), so it gets a looser slack.
        let slack = if scheme == Scheme::Gaussian { 1e-4 * f0 } else { 1e-8 * f0 };
        for pair in out.trace.records.windows(2) {
            assert!(
                pair[1].objective <= pair[0].objective + slack,
                "{scheme:?}: ascent {} → {}",
                pair[0].objective,
                pair[1].objective
            );
        }
        assert!(
            out.trace.final_objective() < 0.9 * f0,
            "{scheme:?}: no progress ({} vs f0 {f0})",
            out.trace.final_objective()
        );
        assert!(out.beta >= 1.0, "{scheme:?}: achieved β {}", out.beta);
    }
}

#[test]
fn smoke_matrix_lbfgs_all_schemes() {
    let (x, y, _) = gaussian_linear(N, P, 0.3, 9);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f0 = prob.objective(&[0.0; P]);
    for &scheme in all_schemes() {
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(scheme)
            .workers(M)
            .wait_for(M)
            .redundancy(2.0)
            .seed(9)
            .label(scheme.name())
            .eval(|w| (prob.objective(w), 0.0))
            .run(Lbfgs::new().iters(25).lambda(0.05))
            .unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        // ρ-damped exact line search on a quadratic: monotone descent
        let slack = if scheme == Scheme::Gaussian { 1e-4 * f0 } else { 1e-8 * f0 };
        for pair in out.trace.records.windows(2) {
            assert!(
                pair[1].objective <= pair[0].objective + slack,
                "{scheme:?}: ascent {} → {}",
                pair[0].objective,
                pair[1].objective
            );
        }
        assert!(
            out.trace.final_objective() < 0.5 * f0,
            "{scheme:?}: poor progress {} vs f0 {f0}",
            out.trace.final_objective()
        );
    }
}

#[test]
fn smoke_matrix_prox_all_schemes() {
    let (x, y, _) = sparse_recovery(N, 24, 4, 0.1, 11);
    let prob = LassoProblem::new(x.clone(), y.clone(), 0.05);
    let f0 = prob.objective(&[0.0; 24]);
    for &scheme in all_schemes() {
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(scheme)
            .workers(M)
            .wait_for(M)
            .redundancy(2.0)
            .seed(11)
            .label(scheme.name())
            .eval(|w| (prob.objective(w), 0.0))
            .run(Prox::with_step(0.5 * prob.default_step()).lambda(0.05).iters(40))
            .unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        let slack = if scheme == Scheme::Gaussian { 1e-4 * f0 } else { 1e-8 * f0 };
        for pair in out.trace.records.windows(2) {
            assert!(
                pair[1].objective <= pair[0].objective + slack,
                "{scheme:?}: ascent {} → {}",
                pair[0].objective,
                pair[1].objective
            );
        }
        assert!(out.trace.final_objective() < f0, "{scheme:?}");
    }
}

#[test]
fn smoke_matrix_bcd_encoded_schemes() {
    // Model parallelism lifts the coordinate space; Replication is a
    // data-parallel partitioning strategy, so BCD runs the encoding
    // schemes plus uncoded.
    let (x, y, _) = gaussian_linear(40, 12, 0.2, 13);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.0);
    let f0 = prob.objective(&[0.0; 12]);
    let step = 0.5 * 40.0 / x.gram_spectral_norm(60, 5);
    for scheme in [
        Scheme::Uncoded,
        Scheme::Gaussian,
        Scheme::Paley,
        Scheme::Hadamard,
        Scheme::Steiner,
        Scheme::Haar,
    ] {
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(scheme)
            .workers(M)
            .wait_for(M)
            .redundancy(2.0)
            .seed(13)
            .label(scheme.name())
            .eval(|w| (prob.objective(w), 0.0))
            .run(Bcd::with_step(step).iters(60))
            .unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        // monotone after the one-round-staleness transient at t=0→1
        for pair in out.trace.records.windows(2).skip(1) {
            assert!(
                pair[1].objective <= pair[0].objective + 1e-8 * f0,
                "{scheme:?}: ascent {} → {}",
                pair[0].objective,
                pair[1].objective
            );
        }
        assert!(
            out.trace.final_objective() < 0.7 * f0,
            "{scheme:?}: poor progress {} vs f0 {f0}",
            out.trace.final_objective()
        );
        assert_eq!(out.w.len(), 12, "{scheme:?}: w must be the original dim");
    }
}

#[test]
fn smoke_async_solvers() {
    let (x, y, _) = gaussian_linear(N, P, 0.2, 15);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f0 = prob.objective(&[0.0; P]);
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .workers(M)
        .timing(1e-4, 1e-3)
        .eval(|w| (prob.objective(w), 0.0))
        .run(
            AsyncGd::with_step(0.3 / prob.smoothness())
                .lambda(0.05)
                .updates(2000)
                .record_every(100),
        )
        .unwrap();
    assert!(out.trace.final_objective() < 0.5 * f0, "async-gd {}", out.trace.final_objective());

    let prob0 = RidgeProblem::new(x.clone(), y.clone(), 0.0);
    let step = 0.5 * N as f64 / x.gram_spectral_norm(60, 6);
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .workers(M)
        .timing(1e-4, 1e-3)
        .eval(|w| (prob0.objective(w), 0.0))
        .run(AsyncBcd::with_step(step).updates(800).record_every(100))
        .unwrap();
    assert!(out.trace.final_objective() < 0.5 * f0, "async-bcd {}", out.trace.final_objective());
}
