//! Driver smoke matrix: every encoding [`Scheme`] × every [`Solver`]
//! through the [`Experiment`](coded_opt::driver::Experiment) API, plus
//! bit-identical equivalence against the legacy `run_*` shims the driver
//! replaces (those shims are deprecated and scheduled for removal; the
//! equivalence tests pin the refactor until they go).

#![allow(deprecated)] // the equivalence tests exercise the legacy shims

use coded_opt::cluster::SimCluster;
use coded_opt::config::Scheme;
use coded_opt::coordinator::bcd::{build_model_parallel, quadratic_phi};
use coded_opt::coordinator::{build_data_parallel, GdConfig, LbfgsConfig, ProxConfig};
use coded_opt::data::synth::{gaussian_linear, sparse_recovery};
use coded_opt::delay::{MixtureDelay, NoDelay};
use coded_opt::driver::{AsyncBcd, AsyncGd, Bcd, Experiment, Gd, Lbfgs, Problem, Prox};
use coded_opt::encoding::partition_bounds;
use coded_opt::objectives::{LassoProblem, QuadObjective, RidgeProblem};

/// Dimensions every scheme construction accepts (Replication needs r|m;
/// Paley/Steiner round to feasible internal sizes).
const N: usize = 64;
const P: usize = 8;
const M: usize = 4;

fn all_schemes() -> &'static [Scheme] {
    Scheme::all()
}

// ---------------------------------------------------------------- matrix

#[test]
fn smoke_matrix_gd_all_schemes() {
    let (x, y, _) = gaussian_linear(N, P, 0.3, 7);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f0 = prob.objective(&[0.0; P]);
    for &scheme in all_schemes() {
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(scheme)
            .workers(M)
            .wait_for(M)
            .redundancy(2.0)
            .seed(7)
            .label(scheme.name())
            .eval(|w| (prob.objective(w), 0.0))
            .run(Gd::with_step(0.5 / prob.smoothness()).lambda(0.05).iters(30))
            .unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        assert_eq!(out.trace.len(), 30, "{scheme:?}");
        // full gather + conservative step ⇒ monotone descent on the
        // ORIGINAL objective. Gaussian is only approximately tight
        // (ETFs/Hadamard/Haar are exact), so it gets a looser slack.
        let slack = if scheme == Scheme::Gaussian { 1e-4 * f0 } else { 1e-8 * f0 };
        for pair in out.trace.records.windows(2) {
            assert!(
                pair[1].objective <= pair[0].objective + slack,
                "{scheme:?}: ascent {} → {}",
                pair[0].objective,
                pair[1].objective
            );
        }
        assert!(
            out.trace.final_objective() < 0.9 * f0,
            "{scheme:?}: no progress ({} vs f0 {f0})",
            out.trace.final_objective()
        );
        assert!(out.beta >= 1.0, "{scheme:?}: achieved β {}", out.beta);
    }
}

#[test]
fn smoke_matrix_lbfgs_all_schemes() {
    let (x, y, _) = gaussian_linear(N, P, 0.3, 9);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f0 = prob.objective(&[0.0; P]);
    for &scheme in all_schemes() {
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(scheme)
            .workers(M)
            .wait_for(M)
            .redundancy(2.0)
            .seed(9)
            .label(scheme.name())
            .eval(|w| (prob.objective(w), 0.0))
            .run(Lbfgs::new().iters(25).lambda(0.05))
            .unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        // ρ-damped exact line search on a quadratic: monotone descent
        let slack = if scheme == Scheme::Gaussian { 1e-4 * f0 } else { 1e-8 * f0 };
        for pair in out.trace.records.windows(2) {
            assert!(
                pair[1].objective <= pair[0].objective + slack,
                "{scheme:?}: ascent {} → {}",
                pair[0].objective,
                pair[1].objective
            );
        }
        assert!(
            out.trace.final_objective() < 0.5 * f0,
            "{scheme:?}: poor progress {} vs f0 {f0}",
            out.trace.final_objective()
        );
    }
}

#[test]
fn smoke_matrix_prox_all_schemes() {
    let (x, y, _) = sparse_recovery(N, 24, 4, 0.1, 11);
    let prob = LassoProblem::new(x.clone(), y.clone(), 0.05);
    let f0 = prob.objective(&[0.0; 24]);
    for &scheme in all_schemes() {
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(scheme)
            .workers(M)
            .wait_for(M)
            .redundancy(2.0)
            .seed(11)
            .label(scheme.name())
            .eval(|w| (prob.objective(w), 0.0))
            .run(Prox::with_step(0.5 * prob.default_step()).lambda(0.05).iters(40))
            .unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        let slack = if scheme == Scheme::Gaussian { 1e-4 * f0 } else { 1e-8 * f0 };
        for pair in out.trace.records.windows(2) {
            assert!(
                pair[1].objective <= pair[0].objective + slack,
                "{scheme:?}: ascent {} → {}",
                pair[0].objective,
                pair[1].objective
            );
        }
        assert!(out.trace.final_objective() < f0, "{scheme:?}");
    }
}

#[test]
fn smoke_matrix_bcd_encoded_schemes() {
    // Model parallelism lifts the coordinate space; Replication is a
    // data-parallel partitioning strategy, so BCD runs the encoding
    // schemes plus uncoded.
    let (x, y, _) = gaussian_linear(40, 12, 0.2, 13);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.0);
    let f0 = prob.objective(&[0.0; 12]);
    let step = 0.5 * 40.0 / x.gram_spectral_norm(60, 5);
    for scheme in [
        Scheme::Uncoded,
        Scheme::Gaussian,
        Scheme::Paley,
        Scheme::Hadamard,
        Scheme::Steiner,
        Scheme::Haar,
    ] {
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(scheme)
            .workers(M)
            .wait_for(M)
            .redundancy(2.0)
            .seed(13)
            .label(scheme.name())
            .eval(|w| (prob.objective(w), 0.0))
            .run(Bcd::with_step(step).iters(60))
            .unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        // monotone after the one-round-staleness transient at t=0→1
        for pair in out.trace.records.windows(2).skip(1) {
            assert!(
                pair[1].objective <= pair[0].objective + 1e-8 * f0,
                "{scheme:?}: ascent {} → {}",
                pair[0].objective,
                pair[1].objective
            );
        }
        assert!(
            out.trace.final_objective() < 0.7 * f0,
            "{scheme:?}: poor progress {} vs f0 {f0}",
            out.trace.final_objective()
        );
        assert_eq!(out.w.len(), 12, "{scheme:?}: w must be the original dim");
    }
}

#[test]
fn smoke_async_solvers() {
    let (x, y, _) = gaussian_linear(N, P, 0.2, 15);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f0 = prob.objective(&[0.0; P]);
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .workers(M)
        .timing(1e-4, 1e-3)
        .eval(|w| (prob.objective(w), 0.0))
        .run(
            AsyncGd::with_step(0.3 / prob.smoothness())
                .lambda(0.05)
                .updates(2000)
                .record_every(100),
        )
        .unwrap();
    assert!(out.trace.final_objective() < 0.5 * f0, "async-gd {}", out.trace.final_objective());

    let prob0 = RidgeProblem::new(x.clone(), y.clone(), 0.0);
    let step = 0.5 * N as f64 / x.gram_spectral_norm(60, 6);
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .workers(M)
        .timing(1e-4, 1e-3)
        .eval(|w| (prob0.objective(w), 0.0))
        .run(AsyncBcd::with_step(step).updates(800).record_every(100))
        .unwrap();
    assert!(out.trace.final_objective() < 0.5 * f0, "async-bcd {}", out.trace.final_objective());
}

// ------------------------------------------- equivalence with legacy shims

#[test]
fn driver_gd_bit_identical_to_legacy() {
    let (x, y, _) = gaussian_linear(N, P, 0.3, 21);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let step = 1.0 / prob.smoothness();
    // legacy hand-wired pipeline
    let dp = build_data_parallel(&x, &y, Scheme::Hadamard, M, 2.0, 21).unwrap();
    let asm = dp.assembler.clone();
    let mut cluster =
        SimCluster::new(dp.workers, Box::new(MixtureDelay::paper_bimodal(M, 5)));
    let cfg = GdConfig { k: 3, step, iters: 40, lambda: 0.05, w0: None };
    let legacy = coded_opt::coordinator::run_gd(&mut cluster, &asm, &cfg, "legacy", &|w| {
        (prob.objective(w), 0.0)
    });
    // driver pipeline, identical wiring
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Hadamard)
        .workers(M)
        .wait_for(3)
        .redundancy(2.0)
        .seed(21)
        .delay(|m| Box::new(MixtureDelay::paper_bimodal(m, 5)))
        .eval(|w| (prob.objective(w), 0.0))
        .run(Gd::with_step(step).lambda(0.05).iters(40))
        .unwrap();
    assert_eq!(out.w, legacy.w, "gd iterates must be bit-identical");
    assert_eq!(out.trace.len(), legacy.trace.len());
    for (a, b) in out.trace.records.iter().zip(&legacy.trace.records) {
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
        assert_eq!(a.time.to_bits(), b.time.to_bits());
        assert_eq!(a.k_used, b.k_used);
    }
}

#[test]
fn driver_lbfgs_bit_identical_to_legacy() {
    let (x, y, _) = gaussian_linear(N, P, 0.3, 23);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let dp = build_data_parallel(&x, &y, Scheme::Haar, M, 2.0, 23).unwrap();
    let asm = dp.assembler.clone();
    let mut cluster =
        SimCluster::new(dp.workers, Box::new(MixtureDelay::paper_bimodal(M, 9)));
    let cfg = LbfgsConfig { k: 3, iters: 30, lambda: 0.05, memory: 10, rho: 0.9, w0: None };
    let legacy = coded_opt::coordinator::run_lbfgs(&mut cluster, &asm, &cfg, "legacy", &|w| {
        (prob.objective(w), 0.0)
    });
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Haar)
        .workers(M)
        .wait_for(3)
        .redundancy(2.0)
        .seed(23)
        .delay(|m| Box::new(MixtureDelay::paper_bimodal(m, 9)))
        .eval(|w| (prob.objective(w), 0.0))
        .run(Lbfgs::new().iters(30).lambda(0.05))
        .unwrap();
    assert_eq!(out.w, legacy.w, "lbfgs iterates must be bit-identical");
}

#[test]
fn driver_prox_bit_identical_to_legacy() {
    let (x, y, _) = sparse_recovery(N, 24, 4, 0.1, 25);
    let prob = LassoProblem::new(x.clone(), y.clone(), 0.05);
    let step = prob.default_step();
    let dp = build_data_parallel(&x, &y, Scheme::Steiner, M, 2.0, 25).unwrap();
    let asm = dp.assembler.clone();
    let mut cluster =
        SimCluster::new(dp.workers, Box::new(MixtureDelay::paper_trimodal(M, 3)));
    let cfg = ProxConfig { k: 3, step, iters: 60, lambda: 0.05, w0: None };
    let legacy = coded_opt::coordinator::run_prox(&mut cluster, &asm, &cfg, "legacy", &|w| {
        (prob.objective(w), 0.0)
    });
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Steiner)
        .workers(M)
        .wait_for(3)
        .redundancy(2.0)
        .seed(25)
        .delay(|m| Box::new(MixtureDelay::paper_trimodal(m, 3)))
        .eval(|w| (prob.objective(w), 0.0))
        .run(Prox::with_step(step).lambda(0.05).iters(60))
        .unwrap();
    assert_eq!(out.w, legacy.w, "prox iterates must be bit-identical");
}

#[test]
fn driver_bcd_bit_identical_to_legacy() {
    let (x, y, _) = gaussian_linear(40, 12, 0.2, 27);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.0);
    let step = 0.6 * 40.0 / x.gram_spectral_norm(60, 7);
    let mp = build_model_parallel(
        &x,
        Scheme::Hadamard,
        M,
        2.0,
        step,
        0.0,
        27,
        quadratic_phi(y.clone()),
    )
    .unwrap();
    // materialize the normalized dense blocks the legacy shim expects
    let sbar = mp.recon.sbar_blocks();
    let mut cluster =
        SimCluster::new(mp.workers, Box::new(MixtureDelay::paper_bimodal(M, 11)));
    let cfg = coded_opt::coordinator::bcd::BcdConfig { k: 3, iters: 50 };
    let legacy =
        coded_opt::coordinator::bcd::run_bcd(&mut cluster, &sbar, 40, 12, &cfg, "legacy", &|w| {
            (prob.objective(w), 0.0)
        });
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Hadamard)
        .workers(M)
        .wait_for(3)
        .redundancy(2.0)
        .seed(27)
        .delay(|m| Box::new(MixtureDelay::paper_bimodal(m, 11)))
        .eval(|w| (prob.objective(w), 0.0))
        .run(Bcd::with_step(step).iters(50))
        .unwrap();
    // The lifted dynamics (v, u, pending steps) are bit-identical; only
    // the final w = S̄ᵀv reconstruction differs, because the driver path
    // goes through the structured full-generator apply_t (one FWHT pass)
    // while the legacy shim sums per-block products — a documented
    // reordering of the same sum, so compare within rounding.
    coded_opt::testutil::assert_allclose(&out.w, &legacy.w, 1e-12, "bcd iterates");
}

#[test]
fn driver_async_gd_bit_identical_to_legacy() {
    let (x, y, _) = gaussian_linear(N, P, 0.2, 29);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let step = 0.3 / prob.smoothness();
    let bounds = partition_bounds(N, M);
    let shards: Vec<_> = bounds
        .windows(2)
        .map(|w| (x.row_block(w[0], w[1]), y[w[0]..w[1]].to_vec()))
        .collect();
    let mut delay = NoDelay::new(M);
    let cfg = coded_opt::coordinator::asynchronous::AsyncGdConfig {
        step,
        lambda: 0.05,
        updates: 1500,
        secs_per_unit: 1e-4,
        record_every: 100,
    };
    let legacy = coded_opt::coordinator::asynchronous::run_async_gd(
        &shards,
        &mut delay,
        N,
        P,
        &cfg,
        "legacy",
        &|w| (prob.objective(w), 0.0),
    );
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .workers(M)
        .timing(1e-4, 1e-3)
        .eval(|w| (prob.objective(w), 0.0))
        .run(AsyncGd::with_step(step).lambda(0.05).updates(1500).record_every(100))
        .unwrap();
    assert_eq!(out.w, legacy.w, "async-gd iterates must be bit-identical");
    assert_eq!(out.trace.len(), legacy.trace.len());
}

#[test]
fn driver_async_bcd_bit_identical_to_legacy() {
    let (x, y, _) = gaussian_linear(30, 12, 0.2, 31);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.0);
    let step = 0.5 * 30.0 / x.gram_spectral_norm(60, 8);
    // legacy hand-wired pipeline: uncoded column blocks + quadratic ∇φ
    let bounds = partition_bounds(12, M);
    let blocks: Vec<_> = bounds
        .windows(2)
        .map(|w| x.select_cols(&(w[0]..w[1]).collect::<Vec<_>>()))
        .collect();
    let yc = y.clone();
    let grad_phi = move |u: &[f64]| -> Vec<f64> {
        let n = u.len() as f64;
        u.iter().zip(&yc).map(|(ui, yi)| (ui - yi) / n).collect()
    };
    let mut delay = NoDelay::new(M);
    let cfg = coded_opt::coordinator::asynchronous::AsyncBcdConfig {
        step,
        lambda: 0.0,
        updates: 600,
        secs_per_unit: 1e-4,
        record_every: 100,
    };
    let eval = |v: &[Vec<f64>]| -> (f64, f64) {
        let w: Vec<f64> = v.iter().flatten().copied().collect();
        (prob.objective(&w), 0.0)
    };
    let (legacy_trace, legacy_v, _) = coded_opt::coordinator::asynchronous::run_async_bcd(
        &blocks, &grad_phi, 30, &cfg, &mut delay, "legacy", &eval,
    );
    let legacy_w: Vec<f64> = legacy_v.iter().flatten().copied().collect();
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .workers(M)
        .timing(1e-4, 1e-3)
        .eval(|w| (prob.objective(w), 0.0))
        .run(AsyncBcd::with_step(step).updates(600).record_every(100))
        .unwrap();
    assert_eq!(out.w, legacy_w, "async-bcd iterates must be bit-identical");
    assert_eq!(out.trace.len(), legacy_trace.len());
    for (a, b) in out.trace.records.iter().zip(&legacy_trace.records) {
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
    }
}
