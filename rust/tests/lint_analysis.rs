//! Determinism-contract lint: fixture coverage, allowlist semantics,
//! the clean-tree self-check, and the CLI exit-code contract.
//!
//! Each fixture under `tests/fixtures/lint/<case>/` is a tiny source
//! tree with one known-bad snippet that must produce exactly one
//! finding (or exercise the `lint:allow` mechanics). The fixtures are
//! data, not code — they are never compiled.

use coded_opt::analysis::{lint_path, LintReport, BARE_ALLOW};
use std::path::PathBuf;
use std::process::Command;

fn fixture(case: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/lint").join(case)
}

fn src_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src")
}

fn lint_fixture(case: &str) -> LintReport {
    lint_path(&fixture(case)).expect("fixture tree lints")
}

/// Assert a fixture yields exactly one finding of `rule` at `line`.
fn assert_single(case: &str, rule: &str, line: usize) -> LintReport {
    let report = lint_fixture(case);
    assert_eq!(
        report.findings.len(),
        1,
        "{case}: expected exactly one finding, got {:?}",
        report.findings
    );
    let f = &report.findings[0];
    assert_eq!(f.rule, rule, "{case}: wrong rule: {f:?}");
    assert_eq!(f.line, line, "{case}: wrong line: {f:?}");
    report
}

#[test]
fn fixture_float_total_order() {
    assert_single("float_total_order", "float-total-order", 5);
}

#[test]
fn fixture_wall_clock_zone() {
    assert_single("wall_clock_zone", "wall-clock-zone", 7);
}

/// Pins the socket-engine zone extension from both sides: wall-clock
/// reads in `cluster/socket.rs` / `cluster/wire.rs` are allowed
/// (timeouts need `Instant::now`), while the same read in
/// `cluster/sim.rs` — the virtual-clock engine — still violates.
#[test]
fn fixture_wall_clock_zone_socket() {
    let r = assert_single("wall_clock_zone_socket", "wall-clock-zone", 8);
    assert_eq!(r.findings[0].file, "cluster/sim.rs", "{:?}", r.findings[0]);
}

#[test]
fn fixture_ordered_iteration() {
    let r = assert_single("ordered_iteration", "ordered-iteration", 5);
    assert_eq!(r.findings[0].file, "coordinator/round_state.rs");
}

#[test]
fn fixture_safety_comment_missing() {
    let r = assert_single("safety_comment", "safety-comment", 6);
    assert!(r.findings[0].message.contains("SAFETY"), "{:?}", r.findings[0]);
}

#[test]
fn fixture_safety_comment_outside_zone() {
    // a SAFETY comment does not excuse unsafe outside runtime/
    let r = assert_single("safety_comment_zone", "safety-comment", 7);
    assert!(r.findings[0].message.contains("runtime/"), "{:?}", r.findings[0]);
}

/// The SIMD kernel file is inside the unsafe zone, but the zone never
/// waives the SAFETY-comment requirement.
#[test]
fn fixture_safety_comment_simd_zone_still_needs_comment() {
    let r = assert_single("safety_comment_simd", "safety-comment", 7);
    assert_eq!(r.findings[0].file, "linalg/simd.rs", "{:?}", r.findings[0]);
    assert!(r.findings[0].message.contains("SAFETY"), "{:?}", r.findings[0]);
}

/// …and with the SAFETY comment in place, in-zone unsafe is clean.
#[test]
fn fixture_safety_comment_simd_ok_is_clean() {
    let r = lint_fixture("safety_comment_simd_ok");
    assert!(r.findings.is_empty(), "{:?}", r.findings);
}

#[test]
fn fixture_layer_order_upward_import() {
    let r = assert_single("layer_order", "layer-order", 4);
    assert_eq!(r.findings[0].file, "encoding/mod.rs", "{:?}", r.findings[0]);
    assert!(r.findings[0].message.contains("layer"), "{:?}", r.findings[0]);
}

/// The same edge in the allowed direction (driver → encoding) is clean.
#[test]
fn fixture_layer_order_downward_import_is_clean() {
    let r = lint_fixture("layer_order_ok");
    assert!(r.findings.is_empty(), "{:?}", r.findings);
}

/// analysis/ sits outside the DAG entirely: it may import NOTHING from
/// the crate, not even the bottom layer.
#[test]
fn fixture_layer_order_analysis_imports_nothing() {
    let r = assert_single("layer_order_analysis", "layer-order", 4);
    assert_eq!(r.findings[0].file, "analysis/helper.rs", "{:?}", r.findings[0]);
    assert!(r.findings[0].message.contains("analysis"), "{:?}", r.findings[0]);
}

#[test]
fn fixture_zone_containment_trace_import() {
    let r = assert_single("zone_containment", "zone-containment", 4);
    assert_eq!(r.findings[0].file, "coordinator/mod.rs", "{:?}", r.findings[0]);
    assert!(r.findings[0].message.contains("unsafe"), "{:?}", r.findings[0]);
}

/// A zone's direct parent may re-export it — that is how linalg/mod.rs
/// dispatches into the SIMD kernel without a finding.
#[test]
fn fixture_zone_containment_parent_reexport_is_clean() {
    let r = lint_fixture("zone_containment_parent");
    assert!(r.findings.is_empty(), "{:?}", r.findings);
}

#[test]
fn fixture_eager_buffer_in_streaming_module() {
    let r = assert_single("eager_buffer", "eager-buffer", 5);
    assert_eq!(r.findings[0].file, "encoding/stream.rs", "{:?}", r.findings[0]);
}

/// The identical constructor OUTSIDE the streaming file list is fine.
#[test]
fn fixture_eager_buffer_outside_zone_is_clean() {
    let r = lint_fixture("eager_buffer_ok");
    assert!(r.findings.is_empty(), "{:?}", r.findings);
}

/// analysis/ joined the ordered-iteration trace zone: the lint's own
/// finding order must be deterministic too.
#[test]
fn fixture_ordered_iteration_analysis() {
    let r = assert_single("ordered_iteration_analysis", "ordered-iteration", 6);
    assert_eq!(r.findings[0].file, "analysis/cache.rs");
}

/// Path-like text inside strings and comments must not grow the graph:
/// `driver` is a known module in this fixture, yet only the one real
/// import appears as an edge.
#[test]
fn graph_noise_yields_only_real_edges() {
    let r = lint_fixture("graph_noise");
    assert!(r.findings.is_empty(), "{:?}", r.findings);
    let edges: Vec<(String, String)> = r.graph.edges().into_iter().collect();
    assert_eq!(edges, vec![("data".to_string(), "linalg".to_string())], "{edges:?}");
    let json = r.graph.to_json();
    assert!(json.contains("\"schema\": \"coded-opt/modgraph-v1\""), "{json}");
    assert!(!json.contains("\"line\""), "modgraph must be line-free:\n{json}");
}

/// Two walks of the same tree must serialize byte-identically — the
/// committed `module-graph.json` drift gate depends on this.
#[test]
fn graph_extraction_is_deterministic() {
    let a = lint_path(&src_root()).expect("first walk").graph.to_json();
    let b = lint_path(&src_root()).expect("second walk").graph.to_json();
    assert_eq!(a, b, "modgraph JSON must be byte-stable across walks");
    let c = lint_fixture("graph_noise").graph.to_json();
    let d = lint_fixture("graph_noise").graph.to_json();
    assert_eq!(c, d);
}

#[test]
fn fixture_no_silent_nan_skips_test_code() {
    let r = assert_single("no_silent_nan", "no-silent-nan", 6);
    // the NAN inside #[cfg(test)] produced no second finding
    assert_eq!(r.findings.len(), 1);
}

#[test]
fn fixture_partial_cmp_unwrap() {
    assert_single("no_silent_nan_unwrap", "no-silent-nan", 5);
}

#[test]
fn justified_allow_suppresses_and_is_counted() {
    let r = lint_fixture("allow_ok");
    assert!(r.findings.is_empty(), "{:?}", r.findings);
    assert_eq!(r.suppressed.len(), 1, "{:?}", r.suppressed);
    assert_eq!(r.suppressed[0].rule, "no-silent-nan");
    assert!(
        !r.suppressed[0].justification.is_empty(),
        "justification must be recorded: {:?}",
        r.suppressed[0]
    );
}

#[test]
fn bare_allow_is_itself_a_finding() {
    let r = assert_single("allow_bare", BARE_ALLOW, 6);
    // the underlying violation was still suppressed (and counted)
    assert_eq!(r.suppressed.len(), 1, "{:?}", r.suppressed);
    assert!(r.suppressed[0].justification.is_empty());
}

#[test]
fn unknown_rule_allow_is_a_finding() {
    let r = assert_single("allow_unknown", BARE_ALLOW, 5);
    assert!(r.suppressed.is_empty(), "{:?}", r.suppressed);
    assert!(r.findings[0].message.contains("no-such-rule"), "{:?}", r.findings[0]);
}

/// The repo's own source tree must be clean — this is the same check
/// the blocking CI `lint` job runs via the binary.
#[test]
fn clean_tree_self_check() {
    let report = lint_path(&src_root()).expect("src tree lints");
    assert!(report.files > 30, "walk found the tree ({} files)", report.files);
    assert!(
        report.findings.is_empty(),
        "determinism-contract violations in rust/src:\n{}",
        report.render_human()
    );
    // the known sentinels are allowlisted WITH justifications
    assert!(!report.suppressed.is_empty(), "expected counted allowlist entries");
    for s in &report.suppressed {
        assert!(!s.justification.is_empty(), "bare allow slipped through: {s:?}");
    }
}

/// CLI contract: non-zero exit on every violating fixture, zero on the
/// clean tree, and `--json` emits the v1 schema.
#[test]
fn cli_exit_codes_and_json() {
    let bin = env!("CARGO_BIN_EXE_coded-opt");
    for case in [
        "float_total_order",
        "wall_clock_zone",
        "wall_clock_zone_socket",
        "ordered_iteration",
        "safety_comment",
        "safety_comment_zone",
        "safety_comment_simd",
        "no_silent_nan",
        "no_silent_nan_unwrap",
        "allow_bare",
        "allow_unknown",
        "layer_order",
        "layer_order_analysis",
        "zone_containment",
        "eager_buffer",
        "ordered_iteration_analysis",
    ] {
        let out = Command::new(bin)
            .args(["lint", "--root"])
            .arg(fixture(case))
            .output()
            .expect("spawn coded-opt lint");
        assert!(
            !out.status.success(),
            "{case}: lint must exit non-zero on a violation\nstdout: {}",
            String::from_utf8_lossy(&out.stdout)
        );
    }

    let out = Command::new(bin)
        .args(["lint", "--json", "--root"])
        .arg(src_root())
        .output()
        .expect("spawn coded-opt lint");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "clean tree must exit zero\nstdout: {stdout}\nstderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("\"schema\": \"coded-opt/lint-v1\""), "{stdout}");
    assert!(stdout.contains("\"finding_count\": 0"), "{stdout}");
}

/// The exit-code contract is part of the CLI surface: findings exit 1,
/// IO/usage errors exit 2 — so CI can tell "violations" from "broken
/// invocation" without parsing output.
#[test]
fn cli_exit_code_contract() {
    let bin = env!("CARGO_BIN_EXE_coded-opt");

    // findings → exactly 1
    let out = Command::new(bin)
        .args(["lint", "--root"])
        .arg(fixture("layer_order"))
        .output()
        .expect("spawn coded-opt lint");
    assert_eq!(out.status.code(), Some(1), "findings must exit 1");

    // nonexistent root → exactly 2, with a diagnostic on stderr
    let out = Command::new(bin)
        .args(["lint", "--root", "/nonexistent/coded-opt-lint-root"])
        .output()
        .expect("spawn coded-opt lint");
    assert_eq!(out.status.code(), Some(2), "IO error must exit 2");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("lint error"), "stderr: {err}");

    // unknown --format → usage error, also 2
    let out = Command::new(bin)
        .args(["lint", "--format", "xml", "--root"])
        .arg(fixture("allow_ok"))
        .output()
        .expect("spawn coded-opt lint");
    assert_eq!(out.status.code(), Some(2), "usage error must exit 2");
}

/// `--format github` renders findings as workflow error annotations.
#[test]
fn cli_format_github_emits_annotations() {
    let bin = env!("CARGO_BIN_EXE_coded-opt");
    let out = Command::new(bin)
        .args(["lint", "--format", "github", "--root"])
        .arg(fixture("zone_containment"))
        .output()
        .expect("spawn coded-opt lint");
    assert_eq!(out.status.code(), Some(1));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("::error file=") && stdout.contains("title=zone-containment"),
        "{stdout}"
    );
}

/// `--graph-out` writes the modgraph-v1 artifact CI commits and diffs.
#[test]
fn cli_graph_out_writes_modgraph() {
    let bin = env!("CARGO_BIN_EXE_coded-opt");
    let dir = std::env::temp_dir().join(format!("lint-graph-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("module-graph.json");
    let out = Command::new(bin)
        .args(["lint", "--root"])
        .arg(fixture("graph_noise"))
        .arg("--graph-out")
        .arg(&path)
        .output()
        .expect("spawn coded-opt lint");
    assert!(out.status.success(), "graph_noise fixture is clean");
    let text = std::fs::read_to_string(&path).expect("graph written");
    assert!(text.contains("\"schema\": \"coded-opt/modgraph-v1\""), "{text}");
    assert!(text.contains("\"module_count\": 3"), "{text}");
    assert!(text.contains("{\"from\": \"data\", \"to\": \"linalg\"}"), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--out` writes the same JSON the CI job uploads as an artifact.
#[test]
fn cli_out_writes_report_file() {
    let bin = env!("CARGO_BIN_EXE_coded-opt");
    let dir = std::env::temp_dir().join(format!("lint-report-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("lint-report.json");
    let out = Command::new(bin)
        .args(["lint", "--root"])
        .arg(fixture("allow_ok"))
        .arg("--out")
        .arg(&path)
        .output()
        .expect("spawn coded-opt lint");
    assert!(out.status.success(), "allow_ok fixture is clean");
    let text = std::fs::read_to_string(&path).expect("report written");
    assert!(text.contains("\"suppressed_count\": 1"), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}
