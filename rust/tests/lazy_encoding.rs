//! Lazy-encoding acceptance suite (ISSUE 5): the operator-first
//! [`EncodingOp`] API must be (a) bit-stable — `row_block(i)`
//! regenerates identical bits across calls, (b) numerically faithful —
//! `apply` / `apply_t` / `encode_data` match the stacked-dense referee
//! to ≤1e-12 for all six schemes, and (c) honest about memory — the
//! block-generation probe ([`coded_opt::encoding::probe`]) proves
//! structured schemes (hadamard / steiner / haar / identity) generate
//! ZERO dense generator bytes on any encode path, while the dense
//! ensembles (Gaussian, Paley) generate their blocks per use and cache
//! nothing.
//!
//! The probe is the heap proxy: it counts every dense `S` materialization
//! at the generation sites, so "probe reads 0" ⇔ "no dense block ever
//! existed" — the eager `Encoding::build` this API replaced would have
//! put `N×n×8` bytes on the heap up front for every scheme.

// This suite pins bit-exact float values on purpose; exact equality
// is the contract under test, not an accident (the workspace denies
// clippy::float_cmp for library code).
#![allow(clippy::float_cmp)]

use coded_opt::config::Scheme;
use coded_opt::data::shard::MatSource;
use coded_opt::encoding::{probe, stream, Encoder, EncodingOp, FastPath, SchemeSpec};
use coded_opt::linalg::mat::reference;
use coded_opt::linalg::Mat;
use coded_opt::rng::Pcg64;
use coded_opt::testutil::assert_allclose;

const ALL: [Scheme; 6] = [
    Scheme::Uncoded,
    Scheme::Gaussian,
    Scheme::Hadamard,
    Scheme::Paley,
    Scheme::Steiner,
    Scheme::Haar,
];

const STRUCTURED: [Scheme; 4] =
    [Scheme::Uncoded, Scheme::Hadamard, Scheme::Steiner, Scheme::Haar];

fn random_mat(rng: &mut Pcg64, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.next_f64() - 0.5)
}

fn random_vec(rng: &mut Pcg64, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.next_f64() - 0.5).collect()
}

#[test]
fn row_block_regeneration_is_bit_identical_across_calls() {
    let (n, m) = (48, 4);
    for scheme in ALL {
        let enc = EncodingOp::build(scheme, n, m, 2.0, 11).unwrap();
        for i in 0..enc.workers() {
            let a = enc.row_block(i).to_dense();
            let b = enc.row_block(i).to_dense();
            assert_eq!(
                a.as_slice(),
                b.as_slice(),
                "{scheme:?} block {i}: repeated regeneration must be bit-identical"
            );
            assert_eq!(a.rows(), enc.block_rows(i), "{scheme:?} block {i} rows");
            assert_eq!(a.cols(), enc.n, "{scheme:?} block {i} cols");
        }
        // ...and a second, independently lowered op regenerates the same
        // bits (the generator is a pure function of the spec)
        let twin = EncodingOp::build(scheme, n, m, 2.0, 11).unwrap();
        assert_eq!(
            enc.row_block(0).to_dense().as_slice(),
            twin.row_block(0).to_dense().as_slice(),
            "{scheme:?}: op is a pure function of its SchemeSpec"
        );
    }
}

#[test]
fn apply_paths_match_stacked_dense_referee() {
    let (n, m) = (48, 4);
    let mut rng = Pcg64::new(5);
    for scheme in ALL {
        let enc = EncodingOp::build(scheme, n, m, 2.0, 21).unwrap();
        let subset: Vec<usize> = (0..enc.workers()).collect();
        let s = enc.stack(&subset);
        let x = random_vec(&mut rng, enc.n);
        let u = random_vec(&mut rng, enc.total_rows());
        let tag = format!("{scheme:?}");
        assert_allclose(&enc.apply(&x), &reference::matvec(&s, &x), 1e-12, &tag);
        assert_allclose(&enc.apply_t(&u), &reference::matvec_t(&s, &u), 1e-12, &tag);
        // encode_vec is the sliced full apply
        assert_allclose(&enc.encode_vec(&x).concat(), &enc.apply(&x), 1e-15, &tag);
        // encode_data per worker vs the stacked referee rows
        let xm = random_mat(&mut rng, enc.n, 6);
        let encoded = enc.encode_data(&xm);
        for (i, e) in encoded.iter().enumerate() {
            let rows = s.row_block(enc.block_bounds()[i], enc.block_bounds()[i + 1]);
            let want = reference::matmul(&rows, &xm);
            assert_allclose(e.as_slice(), want.as_slice(), 1e-12, &format!("{tag} worker {i}"));
        }
    }
}

#[test]
fn structured_schemes_generate_no_dense_blocks_on_any_encode_path() {
    let (n, m, p) = (48, 4, 5);
    let mut rng = Pcg64::new(9);
    let x = random_mat(&mut rng, n, p);
    let y = random_vec(&mut rng, n);
    for scheme in STRUCTURED {
        probe::reset();
        let enc = EncodingOp::build(scheme, n, m, 2.0, 7).unwrap();
        let _ = enc.encode_data(&x);
        let _ = enc.encode_vec(&y);
        let _ = enc.apply(&y);
        let u = vec![0.25; enc.total_rows()];
        let _ = enc.apply_t(&u);
        // the out-of-core paths too: streamed all-workers encode and the
        // shard-by-shard row-range encode behind `coded-opt encode`
        let src = MatSource::new(&x, Some(&y), 13);
        let _ = stream::encode_data_streamed(&enc, &src).unwrap();
        let _ = stream::encode_vec_streamed(&enc, &src).unwrap();
        if enc.fast_path() == FastPath::Csr {
            let _ = stream::encode_rows_streamed(&enc, &src, 0, enc.block_rows(0)).unwrap();
        }
        assert_eq!(
            probe::dense_bytes(),
            0,
            "{scheme:?}: a structured scheme materialized dense generator bytes \
             on an encode path"
        );
    }
}

#[test]
fn dense_ensembles_generate_blocks_per_use_and_cache_nothing() {
    let (n, m, p) = (48, 4, 5);
    let mut rng = Pcg64::new(13);
    let x = random_mat(&mut rng, n, p);

    // Gaussian: exactly N·n entries per full encode, regenerated anew on
    // every use (per-use generation, no hidden cache).
    probe::reset();
    let enc = EncodingOp::build(Scheme::Gaussian, n, m, 2.0, 3).unwrap();
    assert_eq!(probe::dense_bytes(), 0, "lowering generates nothing");
    let per_encode = (enc.total_rows() * enc.n * 8) as u64;
    let _ = enc.encode_data(&x);
    assert_eq!(probe::dense_bytes(), per_encode, "one encode = one generation sweep");
    let _ = enc.encode_data(&x);
    assert_eq!(
        probe::dense_bytes(),
        2 * per_encode,
        "a second encode regenerates — nothing was cached on the op"
    );

    // Paley: one transient frame build per use (frame is nn×n).
    probe::reset();
    let enc = EncodingOp::build(Scheme::Paley, n, m, 2.0, 3).unwrap();
    assert_eq!(probe::dense_bytes(), 0, "lowering generates nothing");
    let per_frame = (enc.total_rows() * enc.n * 8) as u64;
    let _ = enc.encode_data(&x);
    assert_eq!(probe::dense_bytes(), per_frame, "one encode = one transient frame");
}

#[test]
fn streamed_dense_encode_regenerates_one_block_at_a_time() {
    // The streamed Gaussian path is worker-outer: across the whole
    // streamed encode it generates exactly the N·n entries of S, once —
    // the same budget as the in-memory encode, with only one block live
    // at any moment (the visitor drops each block before the next).
    let (n, m, p) = (48, 4, 5);
    let mut rng = Pcg64::new(17);
    let x = random_mat(&mut rng, n, p);
    let enc = EncodingOp::build(Scheme::Gaussian, n, m, 2.0, 9).unwrap();
    let src = MatSource::new(&x, None, 7);
    probe::reset();
    let streamed = stream::encode_data_streamed(&enc, &src).unwrap();
    assert_eq!(
        probe::dense_bytes(),
        (enc.total_rows() * enc.n * 8) as u64,
        "streamed dense encode generates each block exactly once"
    );
    let dense = enc.encode_data(&x);
    for (s, d) in streamed.iter().zip(&dense) {
        assert_eq!(s.as_slice(), d.as_slice(), "streamed == in-memory, bit for bit");
    }
}

#[test]
fn structured_resident_set_is_o_n_heap_proxy() {
    // Heap proxy at a size where the eager dense blocks would dominate
    // memory: hadamard n=1024 → N=2048, so eager storage would be
    // N·n·8 = 16 MiB of dense S. The operator answers a full encode
    // with ZERO dense generator bytes; its state is the FwhtOp's three
    // O(N) index/sign vectors — the O(n) scaling the paper's §4.2
    // efficient-encoding claim promises.
    let n = 1024;
    probe::reset();
    let enc = EncodingOp::build(Scheme::Hadamard, n, 8, 2.0, 5).unwrap();
    let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1).sin()).collect();
    let encoded = enc.encode_vec(&y);
    assert_eq!(encoded.len(), 8);
    let u = vec![0.5; enc.total_rows()];
    let _ = enc.apply_t(&u);
    assert_eq!(
        probe::dense_bytes(),
        0,
        "eager build would have generated {} dense bytes; the operator generated none",
        enc.total_rows() * enc.n * 8
    );
}

#[test]
fn spec_roundtrips_through_lower() {
    let spec = SchemeSpec::new(Scheme::Steiner, 28, 4, 2.0, 1);
    let op = spec.lower().unwrap();
    assert_eq!(op.scheme, Scheme::Steiner);
    assert_eq!(op.n, 28);
    assert_eq!(op.workers(), 4);
    assert_eq!(op.fast_path(), FastPath::Csr);
    // infeasible specs fail at lower(), not at first use
    assert!(SchemeSpec::new(Scheme::Gaussian, 0, 4, 2.0, 1).lower().is_err());
    assert!(SchemeSpec::new(Scheme::Gaussian, 16, 4, 0.5, 1).lower().is_err());
}
