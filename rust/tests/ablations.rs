//! Ablations over the design choices DESIGN.md calls out: redundancy β,
//! wait-fraction η, adaptive-k scheduling, and the two encoding
//! randomizations (row permutation, column signs).

use coded_opt::cluster::{Gather, Task};
use coded_opt::config::Scheme;
use coded_opt::coordinator::schedule::AdaptiveOverlapK;
use coded_opt::coordinator::KIND_GRADIENT;
use coded_opt::data::synth::gaussian_linear;
use coded_opt::delay::{AdversarialDelay, MixtureDelay};
use coded_opt::driver::{Experiment, Gd, Problem};
use coded_opt::encoding::EncodingOp;
use coded_opt::linalg::symmetric_eigenvalues;
use coded_opt::objectives::{QuadObjective, RidgeProblem};

/// β ablation: larger redundancy tightens the subset spectra (smaller
/// ε) monotonically in the operating range.
#[test]
fn ablation_beta_tightens_spectrum() {
    let n = 48;
    let m = 8;
    let k = 6;
    let mut eps = Vec::new();
    for beta in [1.5f64, 2.0, 3.0] {
        let enc = EncodingOp::build(Scheme::Gaussian, n, m, beta, 11).unwrap();
        let mut an = coded_opt::encoding::SubsetSpectrum::new(&enc, 5);
        let stats = an.analyze(k, 10);
        eps.push(stats.epsilon());
    }
    // monotone-ish tightening, and a solid overall improvement. (At these
    // small n the Gaussian MP band keeps ε above 1 — ETFs, not raw ε<1,
    // are what the theory uses; here we ablate the TREND in β.)
    assert!(eps[1] < eps[0] + 0.05 && eps[2] < eps[1] + 0.05, "not monotone: {eps:?}");
    assert!(eps[2] < 0.75 * eps[0], "β=3 should tighten ε vs β=1.5: {eps:?}");
}

/// η ablation: final GD suboptimality under a fixed adversary decreases
/// as the master waits for more workers.
#[test]
fn ablation_eta_improves_approximation() {
    let (x, y, _) = gaussian_linear(96, 12, 0.3, 3);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f_star = prob.objective(&prob.solve_exact());
    let step = 1.0 / prob.smoothness();
    let mut subopts = Vec::new();
    for k in [4usize, 6, 8] {
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(Scheme::Hadamard)
            .workers(8)
            .wait_for(k)
            .redundancy(2.0)
            .seed(3)
            // rotating adversary so every k sees erasures
            .delay(|m| Box::new(AdversarialDelay::rotating(m, 0.25, 1e6)))
            .label("eta")
            .eval(|w| (prob.objective(w), 0.0))
            .run(Gd::with_step(step).lambda(0.05).iters(250))
            .unwrap();
        subopts.push((out.trace.final_objective() - f_star) / f_star);
    }
    assert!(
        subopts[2] <= subopts[0] + 1e-9,
        "k=8 subopt {} should beat k=4 {}",
        subopts[2],
        subopts[0]
    );
}

/// Adaptive-k (paper §3.3): under bimodal delays, the adaptive overlap
/// policy picks k ≥ the fixed overlap target and keeps the L-BFGS
/// curvature overlap ≥ m/β in (almost) every round.
#[test]
fn ablation_adaptive_k_maintains_overlap() {
    let m = 16;
    let beta = 2.0;
    let policy = AdaptiveOverlapK::new(m, beta, 4);
    let (x, y, _) = gaussian_linear(128, 8, 0.3, 5);
    let mut parts = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Hadamard)
        .workers(m)
        .redundancy(beta)
        .seed(5)
        .delay(|m| Box::new(MixtureDelay::paper_bimodal(m, 7)))
        .assemble_data_parallel()
        .unwrap();
    let cluster = &mut parts.cluster;
    let w = vec![0.0; 8];
    // probe with full gathers to see complete arrival orders, then ask
    // the policy what k it WOULD have chosen, and verify overlap.
    let mut prev_active: Vec<usize> = (0..m).collect();
    let mut satisfied = 0;
    let rounds = 20;
    for t in 0..rounds {
        let rr = cluster.round(m, &mut |_| Task {
            iter: t,
            kind: KIND_GRADIENT,
            payload: w.clone(),
            aux: vec![],
        });
        let order = rr.arrival_order();
        let k = policy.pick_k(&order, &prev_active);
        let chosen: std::collections::BTreeSet<usize> = order[..k].iter().copied().collect();
        let overlap = prev_active.iter().filter(|i| chosen.contains(i)).count();
        if overlap * 2 > m || k == m {
            satisfied += 1; // overlap > m/β = m/2, or policy hit its cap
        }
        prev_active = order[..k].to_vec();
    }
    assert!(
        satisfied >= rounds - 1,
        "adaptive policy kept the overlap condition in only {satisfied}/{rounds} rounds"
    );
}

/// Randomization ablation: the row permutation + column signs are what
/// keep block-subsampled structured frames full-rank. Verify the
/// *shipped* constructions never collapse where naive ones could: the
/// minimum eigenvalue over all leave-two-out subsets stays positive for
/// Hadamard at β=2, η=0.75.
#[test]
fn ablation_randomization_prevents_rank_collapse() {
    let n = 32;
    let m = 8;
    let enc = EncodingOp::build(Scheme::Hadamard, n, m, 2.0, 13).unwrap();
    // all C(8,2)=28 leave-two-out subsets — exhaustive worst case
    let mut worst = f64::INFINITY;
    for a in 0..m {
        for b in a + 1..m {
            let subset: Vec<usize> = (0..m).filter(|&i| i != a && i != b).collect();
            let g = enc.gram_normalized(&subset);
            let eigs = symmetric_eigenvalues(&g);
            worst = worst.min(eigs[0]);
        }
    }
    assert!(worst > 1e-3, "leave-two-out λmin = {worst}");
}

/// Encoding-vs-sketching sanity (paper §1 related work): encoding keeps
/// the FULL optimum when all respond, unlike a k/m row-sketch which
/// only approximates it. (Ablation of "why lift dimensions up instead
/// of down".)
#[test]
fn ablation_encoding_beats_sketching_at_equal_compute() {
    let (x, y, _) = gaussian_linear(96, 12, 0.5, 9);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f_star = prob.objective(&prob.solve_exact());
    // encoded, k=6 of 8 (compute ≈ 2·(6/8) = 1.5× data passes)
    let step = 1.0 / prob.smoothness();
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Hadamard)
        .workers(8)
        .wait_for(6)
        .redundancy(2.0)
        .seed(9)
        .delay(|m| Box::new(AdversarialDelay::new(m, vec![0, 5], 1e6)))
        .label("enc")
        .eval(|w| (prob.objective(w), 0.0))
        .run(Gd::with_step(step).lambda(0.05).iters(300))
        .unwrap();
    let encoded_sub = (out.trace.final_objective() - f_star) / f_star;
    // sketch: solve on a fixed 60% row subsample exactly
    let keep = 58; // ≈ 0.6·96
    let xs = x.row_block(0, keep);
    let ys = y[..keep].to_vec();
    let sketch = RidgeProblem::new(xs, ys, 0.05);
    let w_sketch = sketch.solve_exact();
    let sketch_sub = (prob.objective(&w_sketch) - f_star) / f_star;
    assert!(
        encoded_sub < sketch_sub,
        "encoded {encoded_sub} should beat sketch {sketch_sub}"
    );
}
