//! Frame invariants across ALL encoders over a size sweep, via the
//! mini property-testing framework (`coded_opt::testutil::prop`):
//!
//! - Parseval tightness `SᵀS = β̂·I` (exact for the structured
//!   constructions at the *achieved* β̂, statistical for Gaussian);
//! - row-norm / equiangularity / Welch-bound coherence for the ETFs
//!   (Paley, Steiner) at their natural sizes;
//! - erasure-spectrum sanity over random active sets via
//!   `encoding::spectrum`.

use coded_opt::config::Scheme;
use coded_opt::encoding::{paley, EncodingOp, SubsetSpectrum};
use coded_opt::linalg::dot;
use coded_opt::testutil::PropRunner;

/// Schemes whose construction yields an *exact* tight frame at the
/// achieved redundancy (identity included: β̂ = 1).
const EXACT_SCHEMES: &[Scheme] = &[
    Scheme::Uncoded,
    Scheme::Replication,
    Scheme::Hadamard,
    Scheme::Haar,
    Scheme::Paley,
    Scheme::Steiner,
];

fn full_stack(enc: &EncodingOp) -> coded_opt::linalg::Mat {
    let all: Vec<usize> = (0..enc.workers()).collect();
    enc.stack(&all)
}

#[test]
fn prop_structured_schemes_are_exact_parseval_frames() {
    PropRunner::new("parseval_exact", 0xF7A3E).cases(36).run(
        |g| {
            let scheme = EXACT_SCHEMES[g.usize_in(0, EXACT_SCHEMES.len() - 1)];
            let n = g.usize_in(8, 40);
            let m = g.usize_in(1, 6);
            let seed = g.usize_in(0, 1000) as u64;
            (scheme, n, m, seed)
        },
        |&(scheme, n, m, seed)| {
            let enc = EncodingOp::build(scheme, n, m, 2.0, seed)
                .map_err(|e| format!("{scheme:?} n={n} m={m}: {e}"))?;
            let s = full_stack(&enc);
            if s.cols() != enc.n {
                return Err(format!("{scheme:?}: stacked cols {} != n {}", s.cols(), enc.n));
            }
            let g = s.gram();
            let beta = enc.beta;
            let tol = 1e-8 * beta.max(1.0);
            for i in 0..g.rows() {
                for j in 0..g.cols() {
                    let expect = if i == j { beta } else { 0.0 };
                    if (g[(i, j)] - expect).abs() > tol {
                        return Err(format!(
                            "{scheme:?} n={n} m={m} seed={seed}: G[{i},{j}]={} vs {expect} \
                             (β̂={beta})",
                            g[(i, j)]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gaussian_gram_concentrates_at_beta() {
    PropRunner::new("parseval_gaussian", 0x6A55).cases(24).run(
        |g| {
            let n = g.usize_in(32, 96);
            let m = g.usize_in(1, 6);
            let seed = g.usize_in(0, 1000) as u64;
            (n, m, seed)
        },
        |&(n, m, seed)| {
            let enc = EncodingOp::build(Scheme::Gaussian, n, m, 2.0, seed)
                .map_err(|e| e.to_string())?;
            let s = full_stack(&enc);
            let gram = s.gram();
            let beta = enc.beta;
            // diagonal mean: E = β, sd ≈ √(2β)/n — 20% is a ≥ 8σ band
            let diag_mean: f64 =
                (0..n).map(|i| gram[(i, i)]).sum::<f64>() / n as f64;
            if (diag_mean - beta).abs() > 0.2 * beta {
                return Err(format!("diag mean {diag_mean} vs β {beta} (n={n} seed={seed})"));
            }
            // off-diagonal mean |·|: E ≈ √(2β/(πn)) ≤ 0.2 for n ≥ 32
            let mut off_sum = 0.0;
            let mut off_cnt = 0usize;
            for i in 0..n {
                for j in (i + 1)..n {
                    off_sum += gram[(i, j)].abs();
                    off_cnt += 1;
                }
            }
            let off_mean = off_sum / off_cnt as f64;
            if !off_mean.is_finite() || off_mean > 0.4 {
                return Err(format!("off-diag mean {off_mean} too large (n={n} seed={seed})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_etf_rows_unit_norm_and_welch_equiangular() {
    // natural sizes: Paley n = (q+1)/2; Steiner n = v(v−1)/2 — at these
    // sizes the constructions are exact ETFs with unit-norm rows and
    // every pair meeting the Welch bound with equality.
    let cases: &[(Scheme, usize)] =
        &[(Scheme::Paley, 7), (Scheme::Paley, 9), (Scheme::Steiner, 6), (Scheme::Steiner, 28)];
    PropRunner::new("etf_welch", 0xE7F).cases(16).run(
        |g| {
            let (scheme, n) = cases[g.usize_in(0, cases.len() - 1)];
            let m = g.usize_in(1, 4);
            (scheme, n, m)
        },
        |&(scheme, n, m)| {
            let enc = EncodingOp::build(scheme, n, m, 2.0, 1).map_err(|e| e.to_string())?;
            let s = full_stack(&enc);
            let rows = s.rows();
            let beta = rows as f64 / n as f64;
            for i in 0..rows {
                let n2 = dot(s.row(i), s.row(i));
                if (n2 - 1.0).abs() > 1e-8 {
                    return Err(format!("{scheme:?} n={n}: row {i} norm² = {n2}"));
                }
            }
            let welch = ((beta - 1.0) / (beta * n as f64 - 1.0)).sqrt();
            for i in 0..rows {
                for j in (i + 1)..rows {
                    let ip = dot(s.row(i), s.row(j)).abs();
                    if (ip - welch).abs() > 1e-8 {
                        return Err(format!(
                            "{scheme:?} n={n}: |<{i},{j}>| = {ip}, welch = {welch}"
                        ));
                    }
                }
            }
            // and the library helper agrees
            let w = paley::max_coherence(&s);
            if (w - welch).abs() > 1e-8 {
                return Err(format!("max_coherence {w} vs welch {welch}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_erasure_spectrum_sanity_all_schemes() {
    let all = Scheme::all();
    PropRunner::new("erasure_spectrum", 0x5BEC).cases(30).run(
        |g| {
            let scheme = all[g.usize_in(0, all.len() - 1)];
            let n = g.usize_in(12, 36);
            let m = g.usize_in(2, 8);
            let k = g.usize_in(1, m);
            let seed = g.usize_in(0, 500) as u64;
            (scheme, n, m, k, seed)
        },
        |&(scheme, n, m, k, seed)| {
            let enc =
                EncodingOp::build(scheme, n, m, 2.0, seed).map_err(|e| e.to_string())?;
            let stats = SubsetSpectrum::new(&enc, seed ^ 0xabc).analyze(k, 4);
            if stats.eigenvalues.iter().any(|e| !e.is_finite()) {
                return Err("non-finite eigenvalue".into());
            }
            // Grams are PSD: eigenvalues ≥ 0 up to numerics
            if stats.lambda_min < -1e-8 {
                return Err(format!("λmin = {} < 0", stats.lambda_min));
            }
            if stats.lambda_max < stats.lambda_min {
                return Err("λmax < λmin".into());
            }
            if !(0.0..=1.0).contains(&stats.bulk_at_one) {
                return Err(format!("bulk_at_one = {}", stats.bulk_at_one));
            }
            if stats.epsilon() < -1e-12 || !stats.epsilon().is_finite() {
                return Err(format!("ε = {}", stats.epsilon()));
            }
            // k = m with an exact tight frame ⇒ flat spectrum at 1
            if k == m && scheme != Scheme::Gaussian && stats.epsilon() > 1e-7 {
                return Err(format!("{scheme:?}: full-gather ε = {}", stats.epsilon()));
            }
            Ok(())
        },
    );
}
