//! Theory checkpoints: each of the paper's formal statements, verified
//! numerically on concrete instances (DESIGN.md §7).

use coded_opt::cluster::{Gather, Task};
use coded_opt::config::Scheme;
use coded_opt::coordinator::KIND_GRADIENT;
use coded_opt::data::synth::gaussian_linear;
use coded_opt::driver::{Experiment, Gd, Problem};
use coded_opt::delay::AdversarialDelay;
use coded_opt::encoding::{paley, spectrum, EncodingOp};
use coded_opt::linalg::{symmetric_eigenvalues, Mat};
use coded_opt::objectives::{QuadObjective, RidgeProblem};
use coded_opt::rng::{sample_without_replacement, Pcg64};

/// Definition 1 + Lemma 9/10 premise: for tight-frame encodings with
/// η ≥ 1/β, subset Grams are bounded away from singularity — ε < 1.
#[test]
fn brip_epsilon_below_one_for_etfs() {
    for (scheme, n) in [(Scheme::Steiner, 28), (Scheme::Hadamard, 32)] {
        let enc = EncodingOp::build(scheme, n, 8, 2.0, 5).unwrap();
        let mut an = spectrum::SubsetSpectrum::new(&enc, 7);
        let stats = an.analyze(6, 10); // η = 0.75 ≥ 1/β = 0.5
        assert!(
            stats.epsilon() < 1.0,
            "{scheme:?}: ε = {} (λ ∈ [{}, {}])",
            stats.epsilon(),
            stats.lambda_min,
            stats.lambda_max
        );
    }
}

/// Haar caveat (paper §3.1): strict BRIP can fail at the extreme
/// eigenvalues (subsampled-Haar subsets can graze singularity at small
/// n), but "in practice the algorithms perform well as long as the bulk
/// of the eigenvalues of S_A lie within a small interval". Assert the
/// bulk claim, not the worst case.
#[test]
fn haar_bulk_concentrates_even_if_extremes_escape() {
    let enc = EncodingOp::build(Scheme::Haar, 32, 8, 2.0, 5).unwrap();
    let mut an = spectrum::SubsetSpectrum::new(&enc, 7);
    let stats = an.analyze(6, 10);
    let near_one = stats
        .eigenvalues
        .iter()
        .filter(|&&e| (0.5..=1.5).contains(&e))
        .count() as f64
        / stats.eigenvalues.len() as f64;
    assert!(near_one > 0.5, "bulk fraction {near_one}");
    assert!(stats.lambda_max < 2.5, "λmax {}", stats.lambda_max);
}

/// Proposition 7 (Welch bound): every unit-norm frame has
/// ω ≥ √((β−1)/(βn−1)); Paley ETF meets it with equality.
#[test]
fn welch_bound_met_with_equality_only_by_etf() {
    // Paley: equality
    let s = paley::paley_etf(7).unwrap();
    let welch = ((2.0 - 1.0) / (2.0 * 7.0 - 1.0f64)).sqrt();
    assert!((paley::max_coherence(&s) - welch).abs() < 1e-9);
    // Gaussian frame at the same size: strictly above the bound
    let enc = EncodingOp::build(Scheme::Gaussian, 7, 2, 2.0, 3).unwrap();
    let mut g = enc.stack(&[0, 1]);
    // normalize rows to unit norm for a fair coherence comparison
    for i in 0..g.rows() {
        let nrm = coded_opt::linalg::norm2(g.row(i));
        for v in g.row_mut(i) {
            *v /= nrm;
        }
    }
    assert!(paley::max_coherence(&g) > welch + 0.05);
}

/// Proposition 8: subsampled ETF Gram (β-normalized) has at least
/// n(1 − β(1−η)) eigenvalues exactly 1.
#[test]
fn prop8_unit_eigenvalue_count() {
    let enc = EncodingOp::build(Scheme::Steiner, 28, 8, 2.0, 1).unwrap();
    let beta = enc.beta;
    // η = 6/8 = 0.75 → guarantee: 28·(1 − β/4)
    let subset: Vec<usize> = (0..6).collect();
    let guarantee = (28.0 * (1.0 - beta * 0.25)).floor().max(0.0) as usize;
    let count = spectrum::prop8_unit_eigen_count(&enc, &subset, 1e-9);
    assert!(count >= guarantee, "count={count} < guarantee={guarantee}");
}

/// Lemma 9/10 (solution quality): the minimizer ŵ of the subset-encoded
/// problem satisfies f(ŵ) ≤ κ²·f(w*) with κ = (1+ε)/(1−ε).
#[test]
fn lemma10_subset_solution_quality() {
    let (x, y, _) = gaussian_linear(64, 8, 0.5, 9);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.0);
    let f_star = prob.objective(&prob.solve_exact());
    let m = 8;
    let enc = EncodingOp::build(Scheme::Hadamard, 64, m, 2.0, 9).unwrap();
    let mut rng = Pcg64::new(31);
    for _ in 0..5 {
        let subset = sample_without_replacement(&mut rng, m, 6);
        // ε of this subset
        let g = enc.gram_normalized(&subset);
        let eigs = symmetric_eigenvalues(&g);
        let eps = (1.0 - eigs[0]).max(eigs.last().unwrap() - 1.0);
        if eps >= 1.0 {
            continue; // lemma premise violated; skip this subset
        }
        // solve the subset-encoded least squares exactly
        let sa = enc.stack(&subset);
        let norm = 1.0 / (enc.beta * subset.len() as f64 / m as f64).sqrt();
        let mut sax = sa.matmul(&x);
        sax.scale_inplace(norm);
        let mut say = sa.matvec(&y);
        coded_opt::linalg::scale(norm, &mut say);
        let w_hat = coded_opt::linalg::chol::ridge_solve(&sax, &say, 1e-9);
        let f_hat = prob.objective(&w_hat);
        let kappa = (1.0 + eps) / (1.0 - eps);
        assert!(
            f_hat <= kappa * kappa * f_star * (1.0 + 1e-6),
            "f(ŵ)={f_hat} > κ²f* = {} (ε={eps})",
            kappa * kappa * f_star
        );
    }
}

/// Theorem 2 (strongly convex case): encoded GD contracts linearly to a
/// neighborhood — check geometric decrease of the suboptimality over
/// windows until the noise floor.
#[test]
fn theorem2_linear_convergence_band() {
    let (x, y, _) = gaussian_linear(96, 8, 0.3, 11);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.1);
    let f_star = prob.objective(&prob.solve_exact());
    let step = 1.0 / prob.smoothness();
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Hadamard)
        .workers(8)
        .wait_for(6)
        .redundancy(2.0)
        .seed(11)
        .delay(|m| Box::new(AdversarialDelay::rotating(m, 0.25, 1e6)))
        .label("thm2")
        .eval(|w| (prob.objective(w), 0.0))
        .run(Gd::with_step(step).lambda(0.1).iters(300))
        .unwrap();
    // early-phase contraction: subopt at t=50 well below subopt at t=0
    let sub0 = out.trace.records[0].objective - f_star;
    let sub50 = out.trace.records[50].objective - f_star;
    assert!(sub50 < 0.05 * sub0, "no contraction: {sub0} → {sub50}");
    // approximation band: final objective within a modest factor of f*
    let final_sub = (out.trace.final_objective() - f_star) / f_star;
    assert!(final_sub < 0.5, "final band too loose: {final_sub}");
}

/// Lemma 3 premise: overlap-gradient curvature pairs keep the implicit
/// Hessian estimate bounded. Verified via the pair quantities the proof
/// bounds: the secant products stay positive and ‖r‖²/(rᵀu) ≲ (1+ε)M.
#[test]
fn lemma3_pair_curvature_bounds() {
    let (x, y, _) = gaussian_linear(64, 8, 0.3, 13);
    let lambda = 0.05;
    let m = 8;
    let mut parts = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Hadamard)
        .workers(m)
        .redundancy(2.0)
        .seed(13)
        .delay(|m| Box::new(AdversarialDelay::rotating(m, 0.25, 1e6)))
        .assemble_data_parallel()
        .unwrap();
    let (cluster, asm) = (&mut parts.cluster, &parts.assembler);
    // Drive a few gradient iterates and form pairs the way L-BFGS does.
    let mut rng = Pcg64::new(17);
    let mut w: Vec<f64> = (0..8).map(|_| rng.next_f64() - 0.5).collect();
    let mut prev: Option<(Vec<f64>, std::collections::BTreeMap<usize, Vec<f64>>)> = None;
    let m_const = x.gram_spectral_norm(60, 1) / 64.0 + lambda;
    let mut pairs_checked = 0;
    for t in 0..10 {
        let rr = cluster.round(6, &mut |_| Task {
            iter: t,
            kind: KIND_GRADIENT,
            payload: w.clone(),
            aux: vec![],
        });
        let partials: std::collections::BTreeMap<usize, Vec<f64>> =
            rr.responses.iter().map(|r| (r.worker, r.payload.clone())).collect();
        if let Some((w_old, old_partials)) = &prev {
            let mut r = vec![0.0; 8];
            let mut overlap = 0;
            for (wk, p) in &partials {
                if let Some(po) = old_partials.get(wk) {
                    for i in 0..8 {
                        r[i] += p[i] - po[i];
                    }
                    overlap += 1;
                }
            }
            if overlap > 0 {
                coded_opt::linalg::scale(m as f64 / (64.0 * overlap as f64), &mut r);
                let u = coded_opt::linalg::sub(&w, w_old);
                coded_opt::linalg::axpy(lambda, &u, &mut r);
                let ru = coded_opt::linalg::dot(&r, &u);
                let rr2 = coded_opt::linalg::dot(&r, &r);
                assert!(ru > 0.0, "secant condition violated at t={t}");
                let ratio = rr2 / ru;
                assert!(
                    ratio <= 3.0 * m_const,
                    "curvature ratio {ratio} way above (1+ε)M ≈ {}",
                    2.0 * m_const
                );
                pairs_checked += 1;
            }
        }
        prev = Some((w.clone(), partials));
        let g = asm.assemble(&rr.responses);
        coded_opt::linalg::axpy(-0.5 / m_const, &g, &mut w);
    }
    assert!(pairs_checked >= 5, "too few overlap pairs formed");
}

/// Theorem 6 / Lemma 15: the model-parallel lift preserves the optimum —
/// min_v g̃(v) == min_w g(w) for full-column-rank S̄ᵀ.
#[test]
fn lemma15_lift_preserves_optimum() {
    let (x, y, _) = gaussian_linear(40, 10, 0.2, 15);
    let enc = EncodingOp::build(Scheme::Hadamard, 10, 2, 2.0, 15).unwrap();
    let norm = 1.0 / enc.beta.sqrt();
    // lifted design X·S̄ᵀ (40 × βp), assembled column-block by block
    let xt = x.transpose();
    let mut lifted_cols: Vec<Vec<f64>> = Vec::new(); // columns of X·S̄ᵀ
    for i in 0..enc.workers() {
        let mut si_xt = enc.row_block(i).encode_mat(&xt); // b_i × 40
        si_xt.scale_inplace(norm);
        for r in 0..si_xt.rows() {
            lifted_cols.push(si_xt.row(r).to_vec());
        }
    }
    let total_cols = lifted_cols.len();
    let lifted = Mat::from_fn(40, total_cols, |r, c| lifted_cols[c][r]);
    // min ‖lifted·v − y‖² via tiny ridge for numerical stability
    let v = coded_opt::linalg::chol::ridge_solve(&lifted, &y, 1e-10);
    let resid_lift = coded_opt::linalg::sub(&lifted.matvec(&v), &y);
    let w = coded_opt::linalg::chol::ridge_solve(&x, &y, 1e-10);
    let resid_dir = coded_opt::linalg::sub(&x.matvec(&w), &y);
    let a = coded_opt::linalg::dot(&resid_lift, &resid_lift);
    let b = coded_opt::linalg::dot(&resid_dir, &resid_dir);
    assert!((a - b).abs() <= 1e-6 * b.max(1e-9), "lifted {a} vs direct {b}");
}
