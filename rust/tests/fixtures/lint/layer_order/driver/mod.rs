//! Layer stub so the graph knows the `driver` module.

pub struct Experiment;
