//! Lint fixture: an encoding-layer module importing the driver layer.
//! Expected: exactly one `layer-order` finding (line 4).

use crate::driver::Experiment;

pub fn plan(_e: &Experiment) {}
