//! Lint fixture: unsafe in the SIMD kernel file with a SAFETY comment
//! naming the guard — the shape every real kernel in linalg/simd.rs
//! follows. Expected: clean (zero findings).

pub fn axpy_avx2(a: f64, x: &[f64], y: &mut [f64]) {
    let n = x.len();
    // SAFETY: avx2 availability is checked by the dispatcher before any
    // caller reaches this path; pointers cover exactly n elements.
    unsafe { axpy_avx2_body(a, x.as_ptr(), y.as_mut_ptr(), n) }
}
