//! Lint fixture (known-good): a zone's direct parent may re-export it —
//! that is how `linalg/mod.rs` dispatches into the SIMD kernel file.
//! Expected: no findings.

pub mod simd;

pub use self::simd::dot4;
