//! Unsafe-zone stub (no actual unsafe, so no SAFETY comment needed).

pub fn dot4(a: [f64; 4], b: [f64; 4]) -> f64 {
    a[0] * b[0] + a[1] * b[1] + a[2] * b[2] + a[3] * b[3]
}
