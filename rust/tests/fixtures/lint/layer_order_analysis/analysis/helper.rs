//! Lint fixture: analysis/ must depend on no other crate module — not
//! even the bottom layer. Expected: one `layer-order` finding (line 4).

use crate::linalg::Mat;

pub fn rows(_m: &Mat) -> usize {
    0
}
