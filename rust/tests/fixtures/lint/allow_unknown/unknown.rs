//! Lint fixture: an allow directive naming a rule that does not exist.
//! Expected: exactly one `bare-allow` finding, nothing suppressed.

pub fn plain() -> f64 {
    // lint:allow(no-such-rule) — the rule name is wrong, so this is inert
    1.0
}
