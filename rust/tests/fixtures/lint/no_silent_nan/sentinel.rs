//! Lint fixture: NAN literal in library code.
//! Expected: exactly one `no-silent-nan` finding (line 6); the NAN in
//! the test module below must NOT be flagged.

pub fn missing() -> f64 {
    f64::NAN
}

#[cfg(test)]
mod tests {
    #[test]
    fn nan_in_tests_is_fine() {
        assert!(f64::NAN.is_nan());
    }
}
