//! Lint fixture: float sort through a NaN-partial order.
//! Expected: exactly one `float-total-order` finding (line 5).

pub fn sort_delays(v: &mut Vec<f64>) {
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in delays"));
}
