//! Lint fixture: a justified allow directive.
//! Expected: zero findings, exactly one counted suppression.

pub fn sentinel() -> f64 {
    // lint:allow(no-silent-nan) — fixture: documented sentinel with a reason
    f64::NAN
}
