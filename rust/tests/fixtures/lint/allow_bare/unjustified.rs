//! Lint fixture: a bare allow directive (no justification).
//! Expected: the underlying finding is suppressed (and counted), but
//! the directive itself is exactly one `bare-allow` finding.

pub fn sentinel() -> f64 {
    // lint:allow(no-silent-nan)
    f64::NAN
}
