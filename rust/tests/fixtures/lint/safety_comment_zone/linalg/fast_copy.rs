//! Lint fixture: unsafe with a perfectly good SAFETY comment — but in
//! a module outside the allowlisted zones (runtime/, linalg/simd.rs).
//! Expected: exactly one `safety-comment` finding (line 7).

pub fn fast_copy(src: &[f64], dst: &mut [f64]) {
    // SAFETY: both slices have the same length, checked by the caller.
    unsafe {
        std::ptr::copy_nonoverlapping(src.as_ptr(), dst.as_mut_ptr(), src.len());
    }
}
