//! Lint fixture: dense full-matrix constructor inside a streaming
//! module. Expected: exactly one `eager-buffer` finding (line 5).

pub fn assemble(rows: usize, cols: usize) -> Mat {
    let out = Mat::zeros(rows, cols);
    out
}
