//! Lint fixture: the wire codec is a declared wall-clock zone.
//! Expected: no findings in this file.

use std::time::SystemTime;

pub fn frame_stamp() -> SystemTime {
    SystemTime::now()
}
