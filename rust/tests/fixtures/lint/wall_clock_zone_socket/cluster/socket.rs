//! Lint fixture: the socket engine's timeout machinery is a declared
//! wall-clock zone. Expected: no findings in this file.

use std::time::Instant;

pub fn connect_deadline() -> Instant {
    Instant::now()
}
