//! Lint fixture: the virtual-clock sim engine is NOT a wall-clock zone —
//! the socket/wire additions must not widen the zone past themselves.
//! Expected: exactly one `wall-clock-zone` finding (line 8).

use std::time::Instant;

pub fn tick() -> Instant {
    Instant::now()
}
