//! Lint fixture: hash collection in a trace-producing module.
//! Expected: exactly one `ordered-iteration` finding (line 5).

pub struct RoundState {
    pub pending: std::collections::HashMap<usize, f64>,
}
