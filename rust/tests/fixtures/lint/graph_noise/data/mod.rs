//! Lint fixture: path-like text in strings and comments must not grow
//! the module graph. `driver` is a known module here, yet only the one
//! real import below may appear as an edge (data -> linalg).
//!
//! A doc mention of `crate::driver::sweep` is not an import.

pub const HINT: &str = "use crate::driver::sweep; crate::driver::run()";

use crate::linalg::Mat;

// a plain comment naming crate::driver::Experiment is not an import
/// Neither is this doc reference to [`crate::driver::sweep`].
pub fn rows(_m: &Mat) -> usize {
    0
}
