//! Layer stub so the graph knows the `linalg` module.

pub struct Mat;
