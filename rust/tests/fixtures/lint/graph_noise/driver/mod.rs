//! Layer stub: `driver` exists so that leaked noise paths WOULD
//! resolve if extraction ever read strings or comments.

pub fn sweep() {}
