//! Lint fixture (known-good): the same dense constructor OUTSIDE the
//! streaming file list is fine — the rule is zone-scoped, not global.
//! Expected: no findings.

pub fn assemble(rows: usize, cols: usize) -> Mat {
    let out = Mat::zeros(rows, cols);
    out
}
