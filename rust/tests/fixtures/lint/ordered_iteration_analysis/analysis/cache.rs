//! Lint fixture: the lint's own analysis/ tree is trace-affecting too —
//! finding order must be deterministic, so no hash collections.
//! Expected: exactly one `ordered-iteration` finding (line 6).

pub struct Cache {
    pub seen: std::collections::HashMap<String, usize>,
}
