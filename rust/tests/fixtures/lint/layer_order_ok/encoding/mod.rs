//! Layer stub so the graph knows the `encoding` module.

pub struct Encoder;
