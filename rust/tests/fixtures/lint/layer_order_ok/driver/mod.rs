//! Lint fixture (known-good): driver importing DOWN into encoding is
//! exactly what the layering DAG allows. Expected: no findings.

use crate::encoding::Encoder;

pub fn run(_e: &Encoder) {}
