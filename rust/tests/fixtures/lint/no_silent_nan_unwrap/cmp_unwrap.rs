//! Lint fixture: unwrap on a partial-order result, no sort context.
//! Expected: exactly one `no-silent-nan` finding (line 5).

pub fn is_less(a: f64, b: f64) -> bool {
    a.partial_cmp(&b).unwrap() == std::cmp::Ordering::Less
}
