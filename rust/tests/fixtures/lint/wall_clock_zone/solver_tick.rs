//! Lint fixture: wall-clock read outside the declared zones.
//! Expected: exactly one `wall-clock-zone` finding (line 7).

use std::time::Instant;

pub fn tick() -> Instant {
    Instant::now()
}
