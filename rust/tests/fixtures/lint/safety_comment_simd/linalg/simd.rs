//! Lint fixture: unsafe in the SIMD kernel file — which IS in the
//! allowed zone — but with no SAFETY comment. The zone never waives
//! the comment. Expected: exactly one `safety-comment` finding (line 7).

pub fn axpy_avx2(a: f64, x: &[f64], y: &mut [f64]) {
    let n = x.len();
    unsafe { axpy_avx2_body(a, x.as_ptr(), y.as_mut_ptr(), n) }
}
