//! Lint fixture: unsafe inside the allowed zone but with no SAFETY
//! comment. Expected: exactly one `safety-comment` finding (line 6).

pub fn raw_view(v: &[f64]) -> &[u8] {
    let n = v.len() * 8;
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, n) }
}
