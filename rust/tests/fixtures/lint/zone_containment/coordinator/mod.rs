//! Lint fixture: a trace-affecting module importing the unsafe
//! `runtime` zone. Expected: one `zone-containment` finding (line 4).

use crate::runtime::GradExecutor;

pub struct Coordinator {
    pub exec: GradExecutor,
}
