//! Zone stub so the graph knows the `runtime` module (unsafe zone).

pub struct GradExecutor;
