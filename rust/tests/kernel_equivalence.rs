//! Kernel-equivalence property suite: the blocked/parallel compute
//! kernels and every structured fast `apply`/`apply_t` path pinned
//! against the naive dense reference across a size sweep and kernel
//! thread counts ∈ {1, 2, 8}.
//!
//! Equality contracts:
//! - Dense `matvec` / `matvec_t` / `matmul` / `gram` / `matvec_sub`:
//!   **bit-identical** to `linalg::mat::reference` — the chunked
//!   parallelism partitions independent outputs and never reorders a
//!   floating-point sum.
//! - CSR `matvec`: bit-identical to the dense reference product (the
//!   skipped entries are exact zeros, and `x + 0.0` is exact for the
//!   normal values these tests generate).
//! - CSR `matvec_t` above the parallel threshold, and the FWHT
//!   `apply`/`apply_t`: ≤1e-12 of the dense reference — the fixed-chunk
//!   tree reduction / butterfly reorders the sum deterministically
//!   (documented in `linalg::par` and `linalg::sparse`).
//! - SIMD vs scalar (`linalg::simd`): **bit-identical** at every size
//!   and thread count — the AVX2 kernels vectorize across independent
//!   outputs only and run each output's accumulation chain in the
//!   scalar order, so `CODED_OPT_SIMD` can never move a golden trace.
//! - f32 storage (`linalg::precision`): ≤1e-5 of the f64 referee,
//!   explicitly NOT bit-pinned (the rounding is in the storage, not the
//!   accumulation — f32 kernels accumulate in f64).

// This suite pins bit-exact float values on purpose; exact equality
// is the contract under test, not an accident (the workspace denies
// clippy::float_cmp for library code).
#![allow(clippy::float_cmp)]

use std::sync::Mutex;

use coded_opt::config::Scheme;
use coded_opt::encoding::{Encoder, EncodingOp};
use coded_opt::linalg::mat::reference;
use coded_opt::linalg::{fwht, par, simd, Csr, Mat, MatF32};
use coded_opt::rng::Pcg64;
use coded_opt::testutil::assert_allclose;

const THREAD_SWEEP: [usize; 3] = [1, 2, 8];

/// `par::set_threads` is process-global and cargo runs tests of one
/// binary concurrently — every sweeping test holds this lock so another
/// test cannot clobber the knob mid-sweep (correctness would survive —
/// results are thread-count invariant — but the 1/2/8 coverage claim
/// would silently degrade).
static THREAD_KNOB: Mutex<()> = Mutex::new(());

/// Size sweep crossing the chunk (64), k-tile (64), and parallel-work
/// boundaries, including degenerate and ragged shapes.
const SIZES: [(usize, usize); 6] = [(1, 1), (3, 7), (17, 5), (64, 64), (65, 129), (150, 301)];

fn random_mat(rng: &mut Pcg64, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.next_f64() - 0.5)
}

fn random_vec(rng: &mut Pcg64, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.next_f64() - 0.5).collect()
}

#[test]
fn dense_kernels_bit_identical_to_reference_across_threads() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let restore = par::threads();
    for &(rows, cols) in &SIZES {
        let mut rng = Pcg64::new(rows as u64 * 1000 + cols as u64);
        let a = random_mat(&mut rng, rows, cols);
        let b = random_mat(&mut rng, cols, (rows % 90) + 1);
        let x = random_vec(&mut rng, cols);
        let xt = random_vec(&mut rng, rows);
        let want_mv = reference::matvec(&a, &x);
        let want_mvt = reference::matvec_t(&a, &xt);
        let want_mm = reference::matmul(&a, &b);
        let want_gram = reference::gram(&a);
        for &t in &THREAD_SWEEP {
            par::set_threads(t);
            assert_eq!(a.matvec(&x), want_mv, "matvec {rows}x{cols} t={t}");
            assert_eq!(a.matvec_t(&xt), want_mvt, "matvec_t {rows}x{cols} t={t}");
            assert_eq!(a.matmul(&b), want_mm, "matmul {rows}x{cols} t={t}");
            assert_eq!(a.gram(), want_gram, "gram {rows}x{cols} t={t}");
            let mut resid = vec![0.0; rows];
            a.matvec_sub(&x, &xt, &mut resid);
            let want: Vec<f64> = want_mv.iter().zip(&xt).map(|(v, y)| v - y).collect();
            assert_eq!(resid, want, "matvec_sub {rows}x{cols} t={t}");
        }
    }
    par::set_threads(restore);
}

#[test]
fn dense_kernels_bit_identical_above_parallel_threshold() {
    // The SIZES sweep stays below PAR_THRESHOLD (fast in debug builds);
    // this case is sized so matmul/gram/matvec/matvec_t all take the
    // actual scoped-thread path and must still be bit-identical.
    let _guard = THREAD_KNOB.lock().unwrap();
    let restore = par::threads();
    let mut rng = Pcg64::new(41);
    let a = random_mat(&mut rng, 4096, 512);
    let sq = random_mat(&mut rng, 320, 512);
    let b = random_mat(&mut rng, 512, 320);
    let x = random_vec(&mut rng, 512);
    let xt = random_vec(&mut rng, 4096);
    let want_mv = reference::matvec(&a, &x);
    let want_mvt = reference::matvec_t(&a, &xt);
    let want_mm = reference::matmul(&sq, &b);
    let want_gram = reference::gram(&sq);
    for &t in &THREAD_SWEEP {
        par::set_threads(t);
        assert_eq!(a.matvec(&x), want_mv, "matvec t={t}");
        assert_eq!(a.matvec_t(&xt), want_mvt, "matvec_t t={t}");
        assert_eq!(sq.matmul(&b), want_mm, "matmul t={t}");
        assert_eq!(sq.gram(), want_gram, "gram t={t}");
    }
    par::set_threads(restore);
}

/// Structured sparse matrix big enough to engage the tree-reduce
/// `matvec_t` path (nnz past `par::PAR_THRESHOLD`).
fn big_sparse() -> Csr {
    let (rows, cols) = (16512, 128);
    let mut triplets = Vec::new();
    for i in 0..rows {
        for j in 0..cols {
            if (i * 7 + j * 13) % 2 == 0 {
                triplets.push((i, j, ((i % 97) as f64 - 48.0) * 0.01 + (j as f64) * 1e-3));
            }
        }
    }
    assert!(triplets.len() > par::PAR_THRESHOLD, "nnz={}", triplets.len());
    Csr::from_triplets(rows, cols, &triplets)
}

#[test]
fn csr_kernels_match_dense_reference_across_threads() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let restore = par::threads();
    let a = big_sparse();
    let dense = a.to_dense();
    let mut rng = Pcg64::new(77);
    let x = random_vec(&mut rng, a.cols());
    let xt = random_vec(&mut rng, a.rows());
    let want_mv = reference::matvec(&dense, &x);
    let want_mvt = reference::matvec_t(&dense, &xt);
    let mut across: Vec<Vec<f64>> = Vec::new();
    for &t in &THREAD_SWEEP {
        par::set_threads(t);
        // row-parallel matvec keeps the exact sequential order per output
        assert_eq!(a.matvec(&x), want_mv, "csr matvec t={t}");
        // tree-reduced matvec_t: deterministic reorder, ≤1e-12 of dense
        let got = a.matvec_t(&xt);
        assert_allclose(&got, &want_mvt, 1e-12, &format!("csr matvec_t t={t}"));
        across.push(got);
    }
    // ...and bit-identical across thread counts (fixed tree shape)
    assert_eq!(across[0], across[1], "csr matvec_t t=1 vs t=2");
    assert_eq!(across[0], across[2], "csr matvec_t t=1 vs t=8");
    par::set_threads(restore);
}

/// Sizes for the SIMD sweep: every row/col count is chosen so the quad
/// loop leaves a remainder lane (≢ 0 mod 4) or the axpy tail is ragged
/// (≢ 0 mod 8/4), plus one chunk-crossing shape.
const SIMD_SIZES: [(usize, usize); 5] = [(5, 3), (7, 9), (33, 17), (65, 129), (150, 301)];

/// Run `f` once under forced-scalar and once under forced-SIMD,
/// returning both results. `set_forced` is process-global, so callers
/// hold THREAD_KNOB (the same mutex the thread sweeps use). On a
/// machine without AVX2 the "on" leg silently runs scalar too — the
/// bit-equality assertion then holds trivially, and CI's SIMD matrix
/// covers the real thing.
fn scalar_vs_simd<T>(mut f: impl FnMut() -> T) -> (T, T) {
    simd::set_forced(Some(false));
    let scalar = f();
    simd::set_forced(Some(true));
    let vector = f();
    simd::set_forced(None);
    (scalar, vector)
}

#[test]
fn simd_dense_kernels_bit_identical_to_scalar() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let restore = par::threads();
    for &(rows, cols) in &SIMD_SIZES {
        let mut rng = Pcg64::new(rows as u64 * 4096 + cols as u64);
        let a = random_mat(&mut rng, rows, cols);
        let b = random_mat(&mut rng, cols, (rows % 50) + 1);
        let x = random_vec(&mut rng, cols);
        let xt = random_vec(&mut rng, rows);
        for &t in &THREAD_SWEEP {
            par::set_threads(t);
            let tag = format!("{rows}x{cols} t={t}");
            let (s, v) = scalar_vs_simd(|| a.matvec(&x));
            assert_eq!(s, v, "matvec {tag}");
            // …and SIMD output equals the naive reference bit-for-bit,
            // not merely the scalar production kernel.
            assert_eq!(v, reference::matvec(&a, &x), "matvec vs reference {tag}");
            let (s, v) = scalar_vs_simd(|| a.matvec_t(&xt));
            assert_eq!(s, v, "matvec_t {tag}");
            let (s, v) = scalar_vs_simd(|| a.matmul(&b));
            assert_eq!(s, v, "matmul {tag}");
            let (s, v) = scalar_vs_simd(|| a.gram());
            assert_eq!(s, v, "gram {tag}");
            let (s, v) = scalar_vs_simd(|| {
                let mut resid = vec![0.0; rows];
                a.matvec_sub(&x, &xt, &mut resid);
                resid
            });
            assert_eq!(s, v, "matvec_sub {tag}");
        }
    }
    par::set_threads(restore);
}

#[test]
fn simd_csr_and_fwht_bit_identical_to_scalar() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let restore = par::threads();
    // Ragged CSR: row lengths 0..=12 exercise the common-prefix
    // lockstep and every per-lane tail length of the quad kernel.
    let mut triplets = Vec::new();
    for i in 0..37usize {
        for j in 0..(i % 13) {
            triplets.push((i, (j * 5 + i) % 23, (i as f64) * 0.11 - (j as f64) * 0.07));
        }
    }
    let a = Csr::from_triplets(37, 23, &triplets);
    let mut rng = Pcg64::new(91);
    let x = random_vec(&mut rng, 23);
    for &t in &THREAD_SWEEP {
        par::set_threads(t);
        let (s, v) = scalar_vs_simd(|| a.matvec(&x));
        assert_eq!(s, v, "csr matvec t={t}");
    }
    // FWHT at the h<4 base cases and across the butterfly switch-over.
    for n in [2usize, 4, 8, 64, 1024] {
        let base: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let (s, v) = scalar_vs_simd(|| {
            let mut buf = base.clone();
            fwht(&mut buf);
            buf
        });
        assert_eq!(s, v, "fwht n={n}");
    }
    par::set_threads(restore);
}

#[test]
fn f32_storage_tracks_f64_referee_within_tolerance() {
    let mut rng = Pcg64::new(2024);
    let a = random_mat(&mut rng, 150, 67);
    let af = MatF32::from_mat(&a);
    let x = random_vec(&mut rng, 67);
    let xt = random_vec(&mut rng, 150);
    // Not bit-pinned: the contract is a relative tolerance against the
    // f64 referee (storage rounding only; accumulation stays f64).
    let tol = |got: f64, want: f64, tag: &str| {
        assert!(
            (got - want).abs() <= 1e-5 * want.abs().max(1.0),
            "{tag}: got {got}, want {want}"
        );
    };
    let want_mv = reference::matvec(&a, &x);
    for (g, w) in af.matvec(&x).iter().zip(&want_mv) {
        tol(*g, *w, "f32 matvec");
    }
    for (g, w) in af.matvec_t(&xt).iter().zip(reference::matvec_t(&a, &xt)) {
        tol(*g, w, "f32 matvec_t");
    }
    let mut resid = vec![0.0; 150];
    af.matvec_sub(&x, &xt, &mut resid);
    for (i, g) in resid.iter().enumerate() {
        tol(*g, want_mv[i] - xt[i], "f32 matvec_sub");
    }
    // …and the point of the mode: the shard really is half the bytes.
    use coded_opt::linalg::{Precision, PrecisionMat};
    let half = PrecisionMat::demote(a.clone(), Precision::F32);
    let full = PrecisionMat::demote(a.clone(), Precision::F64);
    assert_eq!(half.bytes() * 2, full.bytes(), "f32 storage halves the shard");
    // Exactness where exactness is promised: an f32 matvec equals the
    // f64 matvec of the widened copy bit-for-bit (widening is exact and
    // both accumulate in f64 in the same order).
    assert_eq!(af.matvec(&x), af.to_mat().matvec(&x), "widened-copy bit equality");
}

#[test]
fn every_scheme_apply_paths_match_stacked_dense() {
    let (n, m, beta, seed) = (48, 4, 2.0, 21);
    let mut rng = Pcg64::new(5);
    for &scheme in Scheme::all() {
        let enc = EncodingOp::build(scheme, n, m, beta, seed)
            .unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        let subset: Vec<usize> = (0..enc.workers()).collect();
        let s = enc.stack(&subset);
        let x = random_vec(&mut rng, enc.n);
        let u = random_vec(&mut rng, enc.total_rows());
        let tag = format!("{scheme:?}");
        assert_allclose(&enc.apply(&x), &reference::matvec(&s, &x), 1e-12, &tag);
        assert_allclose(&enc.apply_t(&u), &reference::matvec_t(&s, &u), 1e-12, &tag);
        // encode_vec is the sliced full apply
        assert_allclose(&enc.encode_vec(&x).concat(), &enc.apply(&x), 1e-15, &tag);
    }
}

#[test]
fn every_scheme_fast_encode_matches_naive_dense_encode() {
    let (n, m, beta, seed) = (48, 4, 2.0, 23);
    let mut rng = Pcg64::new(9);
    let x = random_mat(&mut rng, n, 6);
    for &scheme in Scheme::all() {
        let enc = EncodingOp::build(scheme, n, m, beta, seed).unwrap();
        let fast = enc.encode_data(&x);
        assert_eq!(fast.len(), enc.workers());
        for (i, f) in fast.iter().enumerate() {
            let naive = reference::matmul(&enc.row_block(i).to_dense(), &x);
            assert_allclose(f.as_slice(), naive.as_slice(), 1e-12, &format!("{scheme:?}"));
        }
    }
}

#[test]
fn fast_encode_thread_invariant() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let restore = par::threads();
    let mut rng = Pcg64::new(31);
    let x = random_mat(&mut rng, 96, 8);
    for scheme in [Scheme::Hadamard, Scheme::Haar, Scheme::Steiner, Scheme::Gaussian] {
        let enc = EncodingOp::build(scheme, 96, 6, 2.0, 3).unwrap();
        let mut outs: Vec<Vec<Mat>> = Vec::new();
        for &t in &THREAD_SWEEP {
            par::set_threads(t);
            outs.push(enc.encode_data(&x));
        }
        for other in &outs[1..] {
            for (a, b) in outs[0].iter().zip(other) {
                assert_eq!(a, b, "{scheme:?}: encode must be thread-count invariant");
            }
        }
    }
    par::set_threads(restore);
}
