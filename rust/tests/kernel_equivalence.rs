//! Kernel-equivalence property suite: the blocked/parallel compute
//! kernels and every structured fast `apply`/`apply_t` path pinned
//! against the naive dense reference across a size sweep and kernel
//! thread counts ∈ {1, 2, 8}.
//!
//! Equality contracts:
//! - Dense `matvec` / `matvec_t` / `matmul` / `gram` / `matvec_sub`:
//!   **bit-identical** to `linalg::mat::reference` — the chunked
//!   parallelism partitions independent outputs and never reorders a
//!   floating-point sum.
//! - CSR `matvec`: bit-identical to the dense reference product (the
//!   skipped entries are exact zeros, and `x + 0.0` is exact for the
//!   normal values these tests generate).
//! - CSR `matvec_t` above the parallel threshold, and the FWHT
//!   `apply`/`apply_t`: ≤1e-12 of the dense reference — the fixed-chunk
//!   tree reduction / butterfly reorders the sum deterministically
//!   (documented in `linalg::par` and `linalg::sparse`).

// This suite pins bit-exact float values on purpose; exact equality
// is the contract under test, not an accident (the workspace denies
// clippy::float_cmp for library code).
#![allow(clippy::float_cmp)]

use std::sync::Mutex;

use coded_opt::config::Scheme;
use coded_opt::encoding::{Encoder, EncodingOp};
use coded_opt::linalg::mat::reference;
use coded_opt::linalg::{par, Csr, Mat};
use coded_opt::rng::Pcg64;
use coded_opt::testutil::assert_allclose;

const THREAD_SWEEP: [usize; 3] = [1, 2, 8];

/// `par::set_threads` is process-global and cargo runs tests of one
/// binary concurrently — every sweeping test holds this lock so another
/// test cannot clobber the knob mid-sweep (correctness would survive —
/// results are thread-count invariant — but the 1/2/8 coverage claim
/// would silently degrade).
static THREAD_KNOB: Mutex<()> = Mutex::new(());

/// Size sweep crossing the chunk (64), k-tile (64), and parallel-work
/// boundaries, including degenerate and ragged shapes.
const SIZES: [(usize, usize); 6] = [(1, 1), (3, 7), (17, 5), (64, 64), (65, 129), (150, 301)];

fn random_mat(rng: &mut Pcg64, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.next_f64() - 0.5)
}

fn random_vec(rng: &mut Pcg64, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.next_f64() - 0.5).collect()
}

#[test]
fn dense_kernels_bit_identical_to_reference_across_threads() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let restore = par::threads();
    for &(rows, cols) in &SIZES {
        let mut rng = Pcg64::new(rows as u64 * 1000 + cols as u64);
        let a = random_mat(&mut rng, rows, cols);
        let b = random_mat(&mut rng, cols, (rows % 90) + 1);
        let x = random_vec(&mut rng, cols);
        let xt = random_vec(&mut rng, rows);
        let want_mv = reference::matvec(&a, &x);
        let want_mvt = reference::matvec_t(&a, &xt);
        let want_mm = reference::matmul(&a, &b);
        let want_gram = reference::gram(&a);
        for &t in &THREAD_SWEEP {
            par::set_threads(t);
            assert_eq!(a.matvec(&x), want_mv, "matvec {rows}x{cols} t={t}");
            assert_eq!(a.matvec_t(&xt), want_mvt, "matvec_t {rows}x{cols} t={t}");
            assert_eq!(a.matmul(&b), want_mm, "matmul {rows}x{cols} t={t}");
            assert_eq!(a.gram(), want_gram, "gram {rows}x{cols} t={t}");
            let mut resid = vec![0.0; rows];
            a.matvec_sub(&x, &xt, &mut resid);
            let want: Vec<f64> = want_mv.iter().zip(&xt).map(|(v, y)| v - y).collect();
            assert_eq!(resid, want, "matvec_sub {rows}x{cols} t={t}");
        }
    }
    par::set_threads(restore);
}

#[test]
fn dense_kernels_bit_identical_above_parallel_threshold() {
    // The SIZES sweep stays below PAR_THRESHOLD (fast in debug builds);
    // this case is sized so matmul/gram/matvec/matvec_t all take the
    // actual scoped-thread path and must still be bit-identical.
    let _guard = THREAD_KNOB.lock().unwrap();
    let restore = par::threads();
    let mut rng = Pcg64::new(41);
    let a = random_mat(&mut rng, 4096, 512);
    let sq = random_mat(&mut rng, 320, 512);
    let b = random_mat(&mut rng, 512, 320);
    let x = random_vec(&mut rng, 512);
    let xt = random_vec(&mut rng, 4096);
    let want_mv = reference::matvec(&a, &x);
    let want_mvt = reference::matvec_t(&a, &xt);
    let want_mm = reference::matmul(&sq, &b);
    let want_gram = reference::gram(&sq);
    for &t in &THREAD_SWEEP {
        par::set_threads(t);
        assert_eq!(a.matvec(&x), want_mv, "matvec t={t}");
        assert_eq!(a.matvec_t(&xt), want_mvt, "matvec_t t={t}");
        assert_eq!(sq.matmul(&b), want_mm, "matmul t={t}");
        assert_eq!(sq.gram(), want_gram, "gram t={t}");
    }
    par::set_threads(restore);
}

/// Structured sparse matrix big enough to engage the tree-reduce
/// `matvec_t` path (nnz past `par::PAR_THRESHOLD`).
fn big_sparse() -> Csr {
    let (rows, cols) = (16512, 128);
    let mut triplets = Vec::new();
    for i in 0..rows {
        for j in 0..cols {
            if (i * 7 + j * 13) % 2 == 0 {
                triplets.push((i, j, ((i % 97) as f64 - 48.0) * 0.01 + (j as f64) * 1e-3));
            }
        }
    }
    assert!(triplets.len() > par::PAR_THRESHOLD, "nnz={}", triplets.len());
    Csr::from_triplets(rows, cols, &triplets)
}

#[test]
fn csr_kernels_match_dense_reference_across_threads() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let restore = par::threads();
    let a = big_sparse();
    let dense = a.to_dense();
    let mut rng = Pcg64::new(77);
    let x = random_vec(&mut rng, a.cols());
    let xt = random_vec(&mut rng, a.rows());
    let want_mv = reference::matvec(&dense, &x);
    let want_mvt = reference::matvec_t(&dense, &xt);
    let mut across: Vec<Vec<f64>> = Vec::new();
    for &t in &THREAD_SWEEP {
        par::set_threads(t);
        // row-parallel matvec keeps the exact sequential order per output
        assert_eq!(a.matvec(&x), want_mv, "csr matvec t={t}");
        // tree-reduced matvec_t: deterministic reorder, ≤1e-12 of dense
        let got = a.matvec_t(&xt);
        assert_allclose(&got, &want_mvt, 1e-12, &format!("csr matvec_t t={t}"));
        across.push(got);
    }
    // ...and bit-identical across thread counts (fixed tree shape)
    assert_eq!(across[0], across[1], "csr matvec_t t=1 vs t=2");
    assert_eq!(across[0], across[2], "csr matvec_t t=1 vs t=8");
    par::set_threads(restore);
}

#[test]
fn every_scheme_apply_paths_match_stacked_dense() {
    let (n, m, beta, seed) = (48, 4, 2.0, 21);
    let mut rng = Pcg64::new(5);
    for &scheme in Scheme::all() {
        let enc = EncodingOp::build(scheme, n, m, beta, seed)
            .unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        let subset: Vec<usize> = (0..enc.workers()).collect();
        let s = enc.stack(&subset);
        let x = random_vec(&mut rng, enc.n);
        let u = random_vec(&mut rng, enc.total_rows());
        let tag = format!("{scheme:?}");
        assert_allclose(&enc.apply(&x), &reference::matvec(&s, &x), 1e-12, &tag);
        assert_allclose(&enc.apply_t(&u), &reference::matvec_t(&s, &u), 1e-12, &tag);
        // encode_vec is the sliced full apply
        assert_allclose(&enc.encode_vec(&x).concat(), &enc.apply(&x), 1e-15, &tag);
    }
}

#[test]
fn every_scheme_fast_encode_matches_naive_dense_encode() {
    let (n, m, beta, seed) = (48, 4, 2.0, 23);
    let mut rng = Pcg64::new(9);
    let x = random_mat(&mut rng, n, 6);
    for &scheme in Scheme::all() {
        let enc = EncodingOp::build(scheme, n, m, beta, seed).unwrap();
        let fast = enc.encode_data(&x);
        assert_eq!(fast.len(), enc.workers());
        for (i, f) in fast.iter().enumerate() {
            let naive = reference::matmul(&enc.row_block(i).to_dense(), &x);
            assert_allclose(f.as_slice(), naive.as_slice(), 1e-12, &format!("{scheme:?}"));
        }
    }
}

#[test]
fn fast_encode_thread_invariant() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let restore = par::threads();
    let mut rng = Pcg64::new(31);
    let x = random_mat(&mut rng, 96, 8);
    for scheme in [Scheme::Hadamard, Scheme::Haar, Scheme::Steiner, Scheme::Gaussian] {
        let enc = EncodingOp::build(scheme, 96, 6, 2.0, 3).unwrap();
        let mut outs: Vec<Vec<Mat>> = Vec::new();
        for &t in &THREAD_SWEEP {
            par::set_threads(t);
            outs.push(enc.encode_data(&x));
        }
        for other in &outs[1..] {
            for (a, b) in outs[0].iter().zip(other) {
                assert_eq!(a, b, "{scheme:?}: encode must be thread-count invariant");
            }
        }
    }
    par::set_threads(restore);
}
