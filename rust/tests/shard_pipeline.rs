//! Out-of-core pipeline acceptance suite.
//!
//! The contract under test (ISSUE 4 / paper §4.2 "efficient mechanisms
//! for encoding large-scale data"):
//! 1. write → manifest → stream → reassemble is bit-identical to the
//!    in-memory matrix;
//! 2. streamed `encode_data` matches the dense `Encoder` output for
//!    every scheme (bit-identical here, which implies the required
//!    ≤ 1e-12);
//! 3. an experiment run from a sharded source produces a trace
//!    bit-identical to the same experiment run from the equivalent
//!    in-memory dataset (same seed / scheme / solver);
//! 4. the sharded code path only ever observes blocks bounded by the
//!    shard size — it consumes the `BlockSource` interface, which has
//!    no whole-matrix accessor, so peak resident input data is one
//!    shard (the `BoundedProbe` wrapper proves every observed block
//!    honors the bound end to end).

// This suite pins bit-exact float values on purpose; exact equality
// is the contract under test, not an accident (the workspace denies
// clippy::float_cmp for library code).
#![allow(clippy::float_cmp)]

use std::path::PathBuf;

use coded_opt::config::Scheme;
use coded_opt::data::shard::{shard_dataset, BlockSource, ShardedSource};
use coded_opt::data::synth::gaussian_linear;
use coded_opt::delay::MixtureDelay;
use coded_opt::driver::{AsyncGd, Bcd, Experiment, Gd, Lbfgs, Problem, Prox, Solver};
use coded_opt::encoding::stream::encode_data_streamed;
use coded_opt::encoding::EncodingOp;
use coded_opt::linalg::Mat;
use coded_opt::metrics::Trace;
use coded_opt::objectives::{QuadObjective, RidgeProblem};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("coded-opt-shard-pipeline-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Assert two traces agree bit-for-bit on everything golden traces pin.
fn assert_traces_bit_identical(a: &Trace, b: &Trace, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: trace length");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.iter, rb.iter, "{ctx}: iter");
        assert_eq!(ra.k_used, rb.k_used, "{ctx}: k_used");
        assert_eq!(
            ra.objective.to_bits(),
            rb.objective.to_bits(),
            "{ctx}: objective bits at iter {}",
            ra.iter
        );
        assert_eq!(
            ra.time.to_bits(),
            rb.time.to_bits(),
            "{ctx}: clock bits at iter {}",
            ra.iter
        );
    }
}

#[test]
fn shard_roundtrip_reassembles_bit_identically() {
    let (x, y, _) = gaussian_linear(130, 11, 0.4, 99);
    let dir = tmpdir("roundtrip");
    let manifest = shard_dataset(&x, Some(&y), &dir, 32).unwrap();
    assert_eq!(manifest.shards.len(), 5, "⌈130/32⌉");
    let src = ShardedSource::open(&dir).unwrap();
    let (x2, y2) = src.load_dense().unwrap();
    assert_eq!(x.as_slice(), x2.as_slice());
    assert_eq!(y, y2.unwrap());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn streamed_encode_from_disk_matches_dense_for_every_scheme() {
    let (x, y, _) = gaussian_linear(48, 6, 0.3, 7);
    let dir = tmpdir("encode-sweep");
    shard_dataset(&x, Some(&y), &dir, 13).unwrap();
    let src = ShardedSource::open(&dir).unwrap();
    for scheme in [
        Scheme::Uncoded,
        Scheme::Gaussian,
        Scheme::Hadamard,
        Scheme::Paley,
        Scheme::Steiner,
        Scheme::Haar,
    ] {
        let enc = EncodingOp::build(scheme, 48, 4, 2.0, 11).unwrap();
        let dense = enc.encode_data(&x);
        let streamed = encode_data_streamed(&enc, &src).unwrap();
        for (w, (sb, db)) in streamed.iter().zip(&dense).enumerate() {
            // bit-identical (strictly stronger than the required 1e-12)
            assert_eq!(
                sb.as_slice(),
                db.as_slice(),
                "{scheme:?} worker {w}: streamed vs dense encode"
            );
            coded_opt::testutil::assert_allclose(
                sb.as_slice(),
                db.as_slice(),
                1e-12,
                "streamed encode",
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Wraps a source and asserts the streaming bound on every observed
/// block — threaded through the full driver build to prove the sharded
/// path never sees (so can never materialize) more than one shard of
/// the input at a time.
struct BoundedProbe<'a> {
    inner: &'a ShardedSource,
    max_seen: std::cell::Cell<usize>,
}

impl BlockSource for BoundedProbe<'_> {
    fn rows(&self) -> usize {
        self.inner.rows()
    }
    fn cols(&self) -> usize {
        self.inner.cols()
    }
    fn has_targets(&self) -> bool {
        self.inner.has_targets()
    }
    fn max_block_rows(&self) -> usize {
        self.inner.max_block_rows()
    }
    fn for_each_block(
        &self,
        f: &mut dyn FnMut(usize, &Mat, &[f64]) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        self.inner.for_each_block(&mut |row0, xb, yb| {
            assert!(
                xb.rows() <= self.inner.max_block_rows(),
                "block of {} rows exceeds shard bound {}",
                xb.rows(),
                self.inner.max_block_rows()
            );
            self.max_seen.set(self.max_seen.get().max(xb.rows()));
            f(row0, xb, yb)
        })
    }
}

#[test]
fn streamed_worker_build_observes_only_bounded_blocks() {
    let (x, y, _) = gaussian_linear(96, 8, 0.5, 5);
    let dir = tmpdir("bounded-build");
    shard_dataset(&x, Some(&y), &dir, 16).unwrap();
    let src = ShardedSource::open(&dir).unwrap();
    let probe = BoundedProbe { inner: &src, max_seen: std::cell::Cell::new(0) };
    for scheme in [Scheme::Hadamard, Scheme::Gaussian, Scheme::Replication] {
        let dp = coded_opt::coordinator::build_data_parallel_streamed(
            &probe,
            scheme,
            8,
            2.0,
            3,
            coded_opt::linalg::Precision::F64,
            None,
        )
        .unwrap();
        assert_eq!(dp.workers.len(), 8);
    }
    assert_eq!(probe.max_seen.get(), 16, "every pass stayed within one shard");
}

#[test]
fn sharded_experiment_trace_is_bit_identical_to_in_memory() {
    let (n, p, m, k) = (96, 8, 8, 6);
    let (x, y, _) = gaussian_linear(n, p, 0.5, 42);
    let dir = tmpdir("experiment");
    shard_dataset(&x, Some(&y), &dir, 16).unwrap();
    let src = ShardedSource::open(&dir).unwrap();
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let step = 1.0 / prob.smoothness();

    let gd = Gd::with_step(step).lambda(0.05).iters(15);
    let lbfgs = Lbfgs::new().lambda(0.05).iters(8);
    let prox = Prox::with_step(step).lambda(0.01).iters(12);
    let cells: Vec<(Scheme, &dyn Solver, &str)> = vec![
        (Scheme::Hadamard, &gd, "hadamard/gd"),
        (Scheme::Gaussian, &lbfgs, "gaussian/lbfgs"),
        (Scheme::Uncoded, &prox, "uncoded/prox"),
        (Scheme::Replication, &gd, "replication/gd"),
    ];
    for (scheme, solver, label) in cells {
        let mem = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(scheme)
            .workers(m)
            .wait_for(k)
            .redundancy(2.0)
            .seed(42)
            .delay(|m| Box::new(MixtureDelay::paper_bimodal(m, 7)))
            .eval(|w| (prob.objective(w), 0.0))
            .run(solver)
            .unwrap();
        let sharded = Experiment::sharded(src.clone())
            .scheme(scheme)
            .workers(m)
            .wait_for(k)
            .redundancy(2.0)
            .seed(42)
            .delay(|m| Box::new(MixtureDelay::paper_bimodal(m, 7)))
            .eval(|w| (prob.objective(w), 0.0))
            .run(solver)
            .unwrap();
        assert_eq!(mem.w, sharded.w, "{label}: final iterate bits");
        assert_eq!(mem.beta, sharded.beta, "{label}: achieved β");
        assert_traces_bit_identical(&mem.trace, &sharded.trace, label);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sharded_async_gd_matches_in_memory() {
    let (x, y, _) = gaussian_linear(64, 6, 0.3, 17);
    let dir = tmpdir("async");
    shard_dataset(&x, Some(&y), &dir, 10).unwrap();
    let src = ShardedSource::open(&dir).unwrap();
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.0);
    let solver = AsyncGd::with_step(0.05 / prob.smoothness()).updates(200).record_every(25);
    let mem = Experiment::new(Problem::least_squares(&x, &y))
        .workers(4)
        .seed(3)
        .eval(|w| (prob.objective(w), 0.0))
        .run(solver)
        .unwrap();
    let sharded = Experiment::sharded(src)
        .workers(4)
        .seed(3)
        .eval(|w| (prob.objective(w), 0.0))
        .run(solver)
        .unwrap();
    assert_eq!(mem.w, sharded.w, "async-gd: uncoded row shards must stream bit-identically");
    assert_traces_bit_identical(&mem.trace, &sharded.trace, "async-gd");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn model_parallel_solvers_reject_sharded_sources_loudly() {
    let (x, y, _) = gaussian_linear(32, 4, 0.2, 1);
    let dir = tmpdir("reject");
    shard_dataset(&x, Some(&y), &dir, 8).unwrap();
    let src = ShardedSource::open(&dir).unwrap();
    let err = Experiment::sharded(src.clone())
        .workers(4)
        .run(Bcd::with_step(0.1).iters(3))
        .unwrap_err();
    assert!(
        err.to_string().contains("sharded"),
        "BCD must name the sharded limitation, got: {err}"
    );
    let err = Experiment::sharded(src)
        .workers(4)
        .run(coded_opt::driver::AsyncBcd::with_step(0.1).updates(10))
        .unwrap_err();
    assert!(err.to_string().contains("sharded"), "got: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}
