//! Integration: rust PJRT runtime × AOT JAX/Pallas artifacts.
//!
//! Requires `make artifacts` (skips gracefully otherwise). Verifies the
//! full L3→L2→L1 bridge: HLO text written by `python/compile/aot.py` is
//! loaded, compiled on the PJRT CPU client, executed with device-resident
//! shard buffers, and its numerics match the rust-native kernel.

use coded_opt::cluster::{Task, WorkerNode};
use coded_opt::config::Scheme;
use coded_opt::coordinator::{QuadWorker, KIND_GRADIENT};
use coded_opt::data::synth::gaussian_linear;
use coded_opt::linalg::Mat;
use coded_opt::rng::Pcg64;
use coded_opt::runtime::{ArtifactIndex, GradExecutor};
use std::path::Path;

fn artifacts() -> Option<ArtifactIndex> {
    let dir = std::env::var("CODED_OPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let idx = ArtifactIndex::load(Path::new(&dir)).expect("manifest parse");
    if idx.is_empty() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        None
    } else {
        Some(idx)
    }
}

fn random_shard(rows: usize, cols: usize, seed: u64) -> (Mat, Vec<f64>) {
    let mut rng = Pcg64::new(seed);
    let sx = Mat::from_fn(rows, cols, |_, _| rng.next_f64() - 0.5);
    let sy: Vec<f64> = (0..rows).map(|_| rng.next_f64() - 0.5).collect();
    (sx, sy)
}

fn native_grad(sx: &Mat, sy: &[f64], w: &[f64]) -> Vec<f64> {
    let mut resid = sx.matvec(w);
    for (r, y) in resid.iter_mut().zip(sy) {
        *r -= y;
    }
    sx.matvec_t(&resid)
}

#[test]
fn pallas_artifact_matches_native_kernel() {
    let Some(idx) = artifacts() else { return };
    for &(rows, cols) in &[(64usize, 32usize), (128, 64), (256, 128)] {
        let (sx, sy) = random_shard(rows, cols, 42 + rows as u64);
        let mut exec = GradExecutor::from_index(&idx, &sx, &sy)
            .unwrap_or_else(|| panic!("no artifact for {rows}x{cols}"));
        let mut rng = Pcg64::new(7);
        for trial in 0..3 {
            let w: Vec<f64> = (0..cols).map(|_| rng.next_f64() - 0.5).collect();
            let got = exec.gradient(&w).expect("pjrt exec");
            let want = native_grad(&sx, &sy, &w);
            let err = coded_opt::testutil::rel_err(&got, &want);
            assert!(err < 1e-4, "{rows}x{cols} trial {trial}: rel err {err}");
        }
        assert_eq!(exec.calls, 3);
    }
}

#[test]
fn pallas_and_jnp_artifacts_agree() {
    let Some(idx) = artifacts() else { return };
    let Some(meta) = idx.find("quad_grad_jnp", 64, 32) else {
        eprintln!("SKIP: no jnp cross-check artifact");
        return;
    };
    let (sx, sy) = random_shard(64, 32, 11);
    // pallas path
    let mut pallas = GradExecutor::from_index(&idx, &sx, &sy).unwrap();
    // jnp path: same spec, different HLO file
    let mut jnp = GradExecutor::new(coded_opt::runtime::GradSpec {
        hlo_path: idx.dir().join(&meta.file),
        rows: 64,
        cols: 32,
        sx: sx.as_slice().iter().map(|&v| v as f32).collect(),
        sy: sy.iter().map(|&v| v as f32).collect(),
    });
    let w: Vec<f64> = (0..32).map(|i| (i as f64 * 0.37).sin()).collect();
    let a = pallas.gradient(&w).unwrap();
    let b = jnp.gradient(&w).unwrap();
    let err = coded_opt::testutil::rel_err(&a, &b);
    assert!(err < 1e-5, "pallas vs jnp rel err {err}");
}

#[test]
fn shape_mismatch_falls_back_cleanly() {
    let Some(idx) = artifacts() else { return };
    // 65 rows: no artifact → from_index returns None, worker uses native.
    let (sx, sy) = random_shard(65, 32, 13);
    assert!(GradExecutor::from_index(&idx, &sx, &sy).is_none());
}

#[test]
fn quadworker_hot_path_runs_on_pjrt() {
    let Some(idx) = artifacts() else { return };
    let (sx, sy) = random_shard(64, 32, 17);
    let mut worker = QuadWorker::new(sx.clone(), sy.clone());
    worker.pjrt = GradExecutor::from_index(&idx, &sx, &sy);
    assert!(worker.pjrt.is_some());
    let w: Vec<f64> = (0..32).map(|i| 0.01 * i as f64).collect();
    let task = Task { iter: 0, kind: KIND_GRADIENT, payload: w.clone(), aux: vec![] };
    let got = worker.process(&task);
    let want = native_grad(&sx, &sy, &w);
    let err = coded_opt::testutil::rel_err(&got, &want);
    assert!(err < 1e-4, "rel err {err}");
    assert_eq!(worker.pjrt.as_ref().unwrap().calls, 1, "must have used PJRT");
}

#[test]
fn encoded_gd_through_pjrt_converges() {
    // Full stack: encoded data-parallel GD where every worker executes
    // the AOT Pallas artifact for its gradient — one Experiment with the
    // runtime attached.
    let Some(idx) = artifacts() else { return };
    let m = 4;
    let (x, y, _) = gaussian_linear(128, 32, 0.2, 23);
    let prob = coded_opt::objectives::RidgeProblem::new(x.clone(), y.clone(), 0.05);
    use coded_opt::objectives::QuadObjective;
    let f_star = prob.objective(&prob.solve_exact());
    // β=2 → 256 encoded rows → 64×32 shards: matches quad_grad_64x32.
    let out = coded_opt::driver::Experiment::new(
        coded_opt::driver::Problem::least_squares(&x, &y),
    )
    .scheme(Scheme::Hadamard)
    .workers(m)
    .wait_for(m)
    .redundancy(2.0)
    .seed(23)
    .runtime(&idx)
    .label("pjrt-gd")
    .eval(|w| (prob.objective(w), 0.0))
    .run(
        coded_opt::driver::Gd::with_step(1.0 / prob.smoothness())
            .lambda(0.05)
            .iters(200),
    )
    .unwrap();
    assert_eq!(out.pjrt_attached, m, "all shards must match an artifact");
    let sub = (out.trace.final_objective() - f_star) / f_star;
    assert!(sub < 1e-5, "subopt {sub}");
}
