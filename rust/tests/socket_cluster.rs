//! Cross-engine conformance suite for the multi-process socket engine.
//!
//! The contract under test (see `cluster::socket`): a recorded delay
//! tape replayed through [`SocketCluster`] across real localhost worker
//! processes produces a trace **bit-identical** to [`SimCluster`]
//! replaying the same tape — and every transport/protocol fault (killed
//! process, torn frame, truncated payload, stale iteration echo, stall,
//! version skew) degrades to a crash-erasure, never a hang or panic.
//!
//! Workers are the real `coded-opt worker` binary
//! (`CARGO_BIN_EXE_coded-opt`) serving encoded partitions written by
//! the real encode pipeline; misbehaving peers come from
//! [`coded_opt::testutil::MisbehavingPeer`].

use std::io::{BufRead, BufReader};
use std::panic::AssertUnwindSafe;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use coded_opt::cluster::{Gather, SocketCluster, Task};
use coded_opt::config::Scheme;
use coded_opt::coordinator::KIND_GRADIENT;
use coded_opt::data::shard::{shard_dataset, ShardedSource};
use coded_opt::data::synth::gaussian_linear;
use coded_opt::delay::{NoDelay, TraceDelay};
use coded_opt::driver::{Engine, Experiment, Gd, Lbfgs, RunOutput, Solver};
use coded_opt::encoding::{stream, EncodingOp};
use coded_opt::scenario::{DelayRecorder, Scenario};
use coded_opt::testutil::{MisbehavingPeer, PeerMode};

const N: usize = 64;
const P: usize = 8;
const BETA: f64 = 2.0;

/// A sharded source dataset plus its encoded worker partitions, in a
/// per-test temp directory (removed on drop).
struct TestData {
    root: PathBuf,
    shards: PathBuf,
    encoded: PathBuf,
    block_rows: Vec<u64>,
}

impl TestData {
    fn partition(&self, w: usize) -> PathBuf {
        self.encoded.join(format!("worker-{w:03}"))
    }
}

impl Drop for TestData {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.root);
    }
}

fn setup(name: &str, m: usize, seed: u64) -> TestData {
    let root =
        std::env::temp_dir().join(format!("coded-opt-socket-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let shards = root.join("shards");
    let encoded = root.join("encoded");
    let (x, y, _) = gaussian_linear(N, P, 0.5, seed);
    shard_dataset(&x, Some(&y), &shards, 16).expect("shard dataset");
    let src = ShardedSource::open(&shards).expect("open shards");
    let enc = EncodingOp::build(Scheme::Hadamard, N, m, BETA, seed).expect("encoding");
    stream::write_encoded_partitions(&enc, &src, &encoded).expect("write partitions");
    let block_rows = (0..m).map(|w| enc.block_rows(w) as u64).collect();
    TestData { root, shards, encoded, block_rows }
}

/// One real `coded-opt worker` child process, killed on drop. The bound
/// address is scraped from the `worker listening on …` banner.
struct WorkerProc {
    child: Child,
    addr: String,
}

impl WorkerProc {
    fn spawn(partition: &Path) -> Self {
        let mut child = Command::new(env!("CARGO_BIN_EXE_coded-opt"))
            .arg("worker")
            .arg("--partition")
            .arg(partition)
            .args(["--listen", "127.0.0.1:0"])
            .stdout(Stdio::piped())
            .spawn()
            .expect("spawn coded-opt worker");
        let stdout = child.stdout.take().expect("piped stdout");
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).expect("read worker banner");
        let addr = line
            .strip_prefix("worker listening on ")
            .and_then(|rest| rest.split_whitespace().next())
            .unwrap_or_else(|| panic!("unexpected worker banner: {line:?}"))
            .to_string();
        WorkerProc { child, addr }
    }

    fn kill(&mut self) {
        self.child.kill().expect("kill worker");
        self.child.wait().expect("reap worker");
    }
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_workers(data: &TestData, m: usize) -> (Vec<WorkerProc>, Vec<String>) {
    let workers: Vec<WorkerProc> =
        (0..m).map(|w| WorkerProc::spawn(&data.partition(w))).collect();
    let addrs = workers.iter().map(|w| w.addr.clone()).collect();
    (workers, addrs)
}

/// Record the delay tape a solver consumes under the `rack-correlated`
/// builtin scenario on the sim engine — the "recorded on one cluster,
/// replayed on another" half of the conformance story.
fn record_tape(
    shards: &Path,
    m: usize,
    k: usize,
    seed: u64,
    solver: impl Solver,
) -> Vec<Vec<f64>> {
    let inner = Scenario::builtin("rack-correlated")
        .expect("builtin scenario")
        .build_delay(m, seed)
        .expect("build delay");
    let (rec, tape) = DelayRecorder::new(inner);
    Experiment::sharded(ShardedSource::open(shards).expect("open shards"))
        .scheme(Scheme::Hadamard)
        .workers(m)
        .wait_for(k)
        .redundancy(BETA)
        .seed(seed)
        .delay_model(Box::new(rec))
        .run(solver)
        .expect("recording run");
    let tape = tape.snapshot();
    assert!(!tape.is_empty(), "recording run sampled no delays");
    tape
}

/// Replay `tape` through the sim engine (`engine: None`) or the socket
/// engine, with an otherwise identical experiment.
fn replay_run(
    shards: &Path,
    m: usize,
    k: usize,
    seed: u64,
    tape: &[Vec<f64>],
    engine: Option<Engine>,
    solver: impl Solver,
) -> RunOutput {
    let sc = Scenario::new("replay").replay(tape.to_vec());
    let mut exp = Experiment::sharded(ShardedSource::open(shards).expect("open shards"))
        .scheme(Scheme::Hadamard)
        .workers(m)
        .wait_for(k)
        .redundancy(BETA)
        .seed(seed)
        .scenario(&sc);
    if let Some(engine) = engine {
        exp = exp.engine(engine);
    }
    exp.run(solver).expect("replay run")
}

/// Bit-level equality of two runs: every trace field and every iterate
/// coordinate compared as raw `f64` bits — no tolerance anywhere.
fn assert_bit_identical(a: &RunOutput, b: &RunOutput, ctx: &str) {
    assert_eq!(
        a.trace.records.len(),
        b.trace.records.len(),
        "{ctx}: trace lengths differ"
    );
    for (i, (ra, rb)) in a.trace.records.iter().zip(&b.trace.records).enumerate() {
        assert_eq!(ra.iter, rb.iter, "{ctx}: record {i}: iter");
        assert_eq!(ra.k_used, rb.k_used, "{ctx}: record {i}: k_used");
        assert_eq!(
            ra.time.to_bits(),
            rb.time.to_bits(),
            "{ctx}: record {i}: time {} vs {}",
            ra.time,
            rb.time
        );
        assert_eq!(
            ra.objective.to_bits(),
            rb.objective.to_bits(),
            "{ctx}: record {i}: objective {} vs {}",
            ra.objective,
            rb.objective
        );
        assert_eq!(
            ra.test_metric.to_bits(),
            rb.test_metric.to_bits(),
            "{ctx}: record {i}: test_metric {} vs {}",
            ra.test_metric,
            rb.test_metric
        );
    }
    assert_eq!(a.w.len(), b.w.len(), "{ctx}: iterate lengths differ");
    for (j, (x, y)) in a.w.iter().zip(&b.w).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: w[{j}]: {x} vs {y}");
    }
}

fn grad_task(iter: usize) -> Task {
    Task { iter, kind: KIND_GRADIENT, payload: vec![0.0; P], aux: Vec::new() }
}

// ---------------------------------------------------------------------
// Conformance: sim and socket produce the same bits on the same tape.
// ---------------------------------------------------------------------

#[test]
fn gd_socket_trace_is_bit_identical_to_sim_and_run_twice_deterministic() {
    let (m, k, seed) = (4, 3, 1234u64);
    let data = setup("gd", m, seed);
    let gd = || Gd::with_step(0.05).lambda(0.05).iters(8);
    let tape = record_tape(&data.shards, m, k, seed, gd());

    let sim = replay_run(&data.shards, m, k, seed, &tape, None, gd());
    let (_workers, addrs) = spawn_workers(&data, m);
    let socket = replay_run(
        &data.shards,
        m,
        k,
        seed,
        &tape,
        Some(Engine::Socket { addrs: addrs.clone() }),
        gd(),
    );
    assert_bit_identical(&sim, &socket, "gd: sim vs socket");

    // Same tape, same live workers (re-accepted sessions), same bits.
    let again = replay_run(
        &data.shards,
        m,
        k,
        seed,
        &tape,
        Some(Engine::Socket { addrs }),
        gd(),
    );
    assert_bit_identical(&socket, &again, "gd: socket run twice");
}

#[test]
fn lbfgs_socket_trace_is_bit_identical_to_sim() {
    let (m, k, seed) = (4, 3, 4321u64);
    let data = setup("lbfgs", m, seed);
    let lbfgs = || Lbfgs::new().lambda(0.05).iters(5);
    let tape = record_tape(&data.shards, m, k, seed, lbfgs());

    let sim = replay_run(&data.shards, m, k, seed, &tape, None, lbfgs());
    let (_workers, addrs) = spawn_workers(&data, m);
    let socket = replay_run(
        &data.shards,
        m,
        k,
        seed,
        &tape,
        Some(Engine::Socket { addrs }),
        lbfgs(),
    );
    assert_bit_identical(&sim, &socket, "lbfgs: sim vs socket");
}

// ---------------------------------------------------------------------
// Fault injection: every fault is a crash-erasure, never a hang/panic.
// ---------------------------------------------------------------------

/// A misbehaving peer that would WIN round 0 (smallest injected delay)
/// must land exactly where a crashed worker lands: the socket run's
/// trace equals a sim run whose tape has that worker at +∞ throughout.
#[test]
fn misbehaving_winner_degrades_to_crash_erasure_bit_identically() {
    let (m, k, seed) = (4, 3, 77u64);
    let data = setup("peer", m, seed);
    let rounds = 5usize;
    // Peer (slot 3, delay 0.0) is the fastest arrival every round; the
    // sim reference crashes that slot for the whole run instead.
    let live_row = vec![0.002, 0.003, 0.004, 0.0];
    let mut dead_row = live_row.clone();
    dead_row[3] = f64::INFINITY;
    let live_tape: Vec<Vec<f64>> = (0..rounds).map(|_| live_row.clone()).collect();
    let dead_tape: Vec<Vec<f64>> = (0..rounds).map(|_| dead_row.clone()).collect();
    let gd = || Gd::with_step(0.05).lambda(0.05).iters(rounds);

    let sim = replay_run(&data.shards, m, k, seed, &dead_tape, None, gd());
    let (_workers, real_addrs) = spawn_workers(&data, 3);
    for mode in
        [PeerMode::TornFrame, PeerMode::TruncatedResult, PeerMode::WrongIterEcho]
    {
        let peer =
            MisbehavingPeer::spawn(mode, data.block_rows[3], P as u64).expect("spawn peer");
        let mut addrs = real_addrs.clone();
        addrs.push(peer.addr().to_string());
        let socket = replay_run(
            &data.shards,
            m,
            k,
            seed,
            &live_tape,
            Some(Engine::Socket { addrs }),
            gd(),
        );
        assert_bit_identical(
            &sim,
            &socket,
            &format!("{mode:?}: sim-with-crashed-slot vs socket"),
        );
    }
}

/// A stalled winner is erased by the I/O timeout — wall clock bounds
/// fault *detection* only — and the next-fastest live worker is
/// promoted so the round still completes.
#[test]
fn stalled_winner_is_erased_by_timeout_and_round_completes() {
    let (m, seed) = (2, 5u64);
    let data = setup("stall", m, seed);
    let worker = WorkerProc::spawn(&data.partition(0));
    let peer =
        MisbehavingPeer::spawn(PeerMode::Stall, data.block_rows[1], P as u64).expect("peer");
    let addrs = vec![worker.addr.clone(), peer.addr().to_string()];
    // Equal costs (equal partition rows), so the peer's 0.0 delay makes
    // it the round-0 winner; the live worker is 0.5 s behind.
    let delay = Box::new(TraceDelay::new(vec![vec![0.5, 0.0]]));
    let mut cluster =
        SocketCluster::connect_with_timeout(&addrs, delay, Duration::from_millis(300))
            .expect("connect");
    let rr = cluster.round(1, &mut |_| grad_task(0));
    assert_eq!(rr.responses.len(), 1);
    assert_eq!(rr.responses[0].worker, 0, "live worker must be promoted into the gap");
    assert_eq!(rr.interrupted, vec![1], "stalled peer ends up interrupted/erased");
    assert!(rr.elapsed.is_finite());
}

/// A peer speaking a different wire version is refused at the
/// handshake, with an error naming the skew — not a garbled session.
#[test]
fn version_skew_peer_is_refused_at_connect() {
    let peer =
        MisbehavingPeer::spawn(PeerMode::WrongVersionHello, 4, P as u64).expect("peer");
    let err = SocketCluster::connect_with_timeout(
        &[peer.addr().to_string()],
        Box::new(NoDelay::new(1)),
        Duration::from_secs(2),
    )
    .err()
    .expect("wrong-version handshake must be refused");
    let msg = format!("{err:#}");
    assert!(msg.contains("protocol version skew"), "unexpected error: {msg}");
}

/// Killing a worker process mid-run erases it permanently (a crash,
/// exactly an infinite delay), and erasing the last worker below `k`
/// fires the same `k ≤ live` assertion SimCluster uses.
#[test]
fn killed_worker_is_erased_and_too_few_live_workers_panics() {
    let (m, seed) = (2, 9u64);
    let data = setup("kill", m, seed);
    let (mut workers, addrs) = spawn_workers(&data, m);
    let delay = Box::new(TraceDelay::new(vec![vec![0.0, 0.0]]));
    let mut cluster =
        SocketCluster::connect_with_timeout(&addrs, delay, Duration::from_secs(5))
            .expect("connect");

    // Round 0: both live; equal arrivals tie-break to worker 0.
    let rr = cluster.round(1, &mut |_| grad_task(0));
    assert_eq!(rr.responses[0].worker, 0);

    // Kill worker 0: the next dispatch to it faults, it is erased, and
    // worker 1 is promoted — the round completes.
    workers[0].kill();
    let rr = cluster.round(1, &mut |_| grad_task(1));
    assert_eq!(rr.responses.len(), 1);
    assert_eq!(rr.responses[0].worker, 1, "killed worker must be erased, not retried");
    assert_eq!(rr.interrupted, vec![0]);

    // Stays dead: later rounds never dispatch to the erased worker.
    let rr = cluster.round(1, &mut |_| grad_task(2));
    assert_eq!(rr.responses[0].worker, 1);

    // Killing the last live worker drops live below k: the round must
    // fail the k ≤ live invariant loudly (SimCluster's exact message),
    // not hang waiting for ghosts.
    workers[1].kill();
    let panic = std::panic::catch_unwind(AssertUnwindSafe(|| {
        cluster.round(1, &mut |_| grad_task(3));
    }))
    .expect_err("round with zero live workers must panic");
    let msg = panic
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("live (non-crashed)"), "unexpected panic payload: {msg:?}");
}
