//! Minimal, API-compatible stand-in for the `anyhow` crate, covering the
//! subset `coded-opt` uses: [`Error`], [`Result`], [`anyhow!`], [`bail!`],
//! [`ensure!`], and the [`Context`] extension trait for `Result`/`Option`.
//!
//! The offline build environment has no crates.io registry; this vendored
//! crate keeps the dependency surface identical so the real `anyhow` can
//! be swapped back in by editing one line of `rust/Cargo.toml`.

use std::error::Error as StdError;
use std::fmt;

/// Boxed dynamic error with an optional source chain, mirroring
/// `anyhow::Error` for the operations used here. Deliberately does NOT
/// implement `std::error::Error`, so the blanket `From<E: Error>` impl
/// below is coherent (same trick as the real crate).
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a plain message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The chain's root message (diagnostics).
    pub fn root_cause_message(&self) -> String {
        match &self.source {
            Some(s) => s.to_string(),
            None => self.msg.clone(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.source.as_deref().map(|s| s as &dyn StdError);
        while let Some(s) = src {
            write!(f, "\n\nCaused by:\n    {s}")?;
            src = s.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>` — `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_context() {
        let r: Result<()> = Err(io_err()).with_context(|| "reading thing".to_string());
        let e = r.unwrap_err();
        assert!(e.to_string().contains("reading thing"));
        assert!(format!("{e:?}").contains("gone"));
    }

    #[test]
    fn option_context() {
        let r: Result<i32> = None.context("missing key");
        assert_eq!(r.unwrap_err().to_string(), "missing key");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(0).unwrap_err().to_string(), "zero");
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }
}
