//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The build environment has no XLA/PJRT shared libraries, so this crate
//! provides the exact API surface `coded_opt::runtime` compiles against,
//! with every constructor returning an error. The runtime layer already
//! treats PJRT failures as "fall back to the native rust kernel", so a
//! stub build is fully functional — just never AOT-accelerated
//! (`pjrt_attached` stays 0). Swap this path dependency for the real
//! `xla` crate to light up the AOT artifact path.

use std::fmt;
use std::path::Path;

/// Stub error: carries a static description of the missing capability.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!("PJRT unavailable ({what}): built with the vendored xla stub"))
}

/// PJRT client handle. The stub can never be constructed, which keeps
/// every downstream method trivially unreachable-but-compilable.
pub struct PjRtClient(());

impl PjRtClient {
    /// CPU client — always fails in the stub build.
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Device buffer (stub; only ever produced by methods that fail).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// Host literal (stub).
pub struct Literal(());

impl Literal {
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("PJRT unavailable"));
    }

    #[test]
    fn hlo_parse_reports_unavailable() {
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo.txt").is_err());
    }
}
