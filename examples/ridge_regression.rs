//! Figure-7-style ridge experiment: uncoded vs replication vs Hadamard
//! coded L-BFGS with k=3m/8 (the paper's k=12, m=32 operating point),
//! under the bimodal straggler mixture. Each run is one
//! [`Experiment`](coded_opt::driver::Experiment).
//!
//!     cargo run --release --example ridge_regression

use coded_opt::config::Scheme;
use coded_opt::data::synth::gaussian_linear;
use coded_opt::delay::MixtureDelay;
use coded_opt::driver::{Experiment, Lbfgs, Problem};
use coded_opt::metrics::TableWriter;
use coded_opt::objectives::{QuadObjective, RidgeProblem};

fn main() -> anyhow::Result<()> {
    // paper: (n,p) = (4096, 6000), m=32, k=12, λ=0.05, β=2 — scaled 4×
    let (n, p, m, k) = (1024, 256, 32, 12);
    let lambda = 0.05;
    let (x, y, _) = gaussian_linear(n, p, 0.5, 99);
    let prob = RidgeProblem::new(x.clone(), y.clone(), lambda);
    let f_star = prob.objective(&prob.solve_exact());
    println!("ridge (Fig. 7 operating point, scaled): n={n} p={p} m={m} k={k} λ={lambda}");
    println!("f* = {f_star:.6}\n");

    let mut table = TableWriter::new(&["scheme", "k", "final subopt", "stable?", "sim time (s)"]);
    for scheme in [Scheme::Uncoded, Scheme::Replication, Scheme::Hadamard] {
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(scheme)
            .workers(m)
            .wait_for(k)
            .redundancy(2.0)
            .seed(5)
            .delay(|m| Box::new(MixtureDelay::paper_bimodal(m, 17)))
            .label(scheme.name())
            .eval(|w| (prob.objective(w), 0.0))
            .run(Lbfgs::new().iters(50).lambda(lambda))?;
        let sub = (out.trace.final_objective() - f_star) / f_star;
        table.row(&[
            scheme.name().into(),
            format!("{k}"),
            format!("{sub:.3e}"),
            format!("{}", out.trace.bounded_by(1.5)),
            format!("{:.1}", out.trace.total_time()),
        ]);
    }
    table.print();
    println!("\nExpected shape (paper Fig. 7): hadamard converges stably; uncoded at");
    println!("fixed k is biased/unstable; replication in between.");
    Ok(())
}
