//! MovieLens-style matrix factorization (paper §5.2): alternating
//! minimization where each large row/column subproblem is solved by
//! DISTRIBUTED ENCODED L-BFGS — one
//! [`Experiment`](coded_opt::driver::Experiment) per subproblem — and
//! small ones locally (the paper's n<500 rule).
//!
//!     cargo run --release --example matrix_factorization

use coded_opt::config::Scheme;
use coded_opt::data::movielens;
use coded_opt::delay::ExponentialDelay;
use coded_opt::driver::{Experiment, Lbfgs, Problem};
use coded_opt::objectives::matfac::{LocalCholesky, MatFacProblem, SubSolver, Subproblem};
use coded_opt::objectives::QuadObjective;

/// The paper's hybrid solver: distributed encoded L-BFGS above the size
/// threshold, local Cholesky below (§5.2).
struct DistributedLbfgs {
    scheme: Scheme,
    m: usize,
    k: usize,
    threshold: usize,
    local: LocalCholesky,
    /// (subproblems solved distributed, locally)
    pub counts: (usize, usize),
}

impl SubSolver for DistributedLbfgs {
    fn solve(&mut self, sub: &Subproblem) -> Vec<f64> {
        if sub.a.rows() < self.threshold {
            self.counts.1 += 1;
            return self.local.solve(sub);
        }
        self.counts.0 += 1;
        let n = sub.a.rows();
        // eq-13 subproblem has unnormalized ‖Aw−b‖² + λ‖w‖²; our ridge
        // convention is 1/(2n)‖·‖² + λ/2‖·‖², so rescale λ.
        let lam = 2.0 * sub.lambda / n as f64;
        let prob = coded_opt::objectives::RidgeProblem::new(sub.a.clone(), sub.b.clone(), lam);
        let out = Experiment::new(Problem::least_squares(&sub.a, &sub.b))
            .scheme(self.scheme)
            .workers(self.m)
            .wait_for(self.k)
            .redundancy(2.0)
            .seed(1)
            .delay(|m| Box::new(ExponentialDelay::new(m, 0.010, 5))) // paper's exp(10ms)
            .label("mf-sub")
            .eval(|w| (prob.objective(w), 0.0))
            .run(Lbfgs::new().iters(15).lambda(lam).memory(8))
            .expect("mf subproblem solve");
        out.w
    }
}

fn main() -> anyhow::Result<()> {
    // paper: MovieLens-1M, p=15, λ=10, b=3; synthetic substitute scaled.
    let (users, movies, p) = (120, 400, 8);
    let ds = movielens::generate(users, movies, p, 60, 0.3, 7);
    println!(
        "ratings: {} train / {} test over {users}×{movies} (p={p})",
        ds.train.len(),
        ds.test.len()
    );
    let mut mf = MatFacProblem::new(&ds.train, users, movies, p, 2.0, ds.global_mean, 3);
    let mut solver = DistributedLbfgs {
        scheme: Scheme::Paley, // the paper's MF tables feature Paley ETF
        m: 8,
        k: 6,
        threshold: 40,
        local: LocalCholesky,
        counts: (0, 0),
    };
    println!("\n{:<7} {:>12} {:>12} {:>12}", "epoch", "train RMSE", "test RMSE", "objective");
    println!(
        "{:<7} {:>12.4} {:>12.4} {:>12.1}",
        0,
        mf.rmse(&ds.train),
        mf.rmse(&ds.test),
        mf.objective(&ds.train)
    );
    for epoch in 1..=5 {
        mf.als_epoch(&mut solver);
        println!(
            "{:<7} {:>12.4} {:>12.4} {:>12.1}",
            epoch,
            mf.rmse(&ds.train),
            mf.rmse(&ds.test),
            mf.objective(&ds.train)
        );
    }
    println!(
        "\nsubproblems: {} distributed (encoded L-BFGS, k=6/8, Paley), {} local (Cholesky)",
        solver.counts.0, solver.counts.1
    );
    println!("Paper's Tables 2–3 shape: coded schemes ≈ perfect RMSE at k<m.");
    Ok(())
}
