//! Figure-5/6-style spectrum analysis: eigenvalue distribution of the
//! normalized subset Gram `(1/(ηβ))·S_AᵀS_A` for every construction.
//!
//!     cargo run --release --example spectrum_analysis

use coded_opt::config::Scheme;
use coded_opt::encoding::{EncodingOp, SubsetSpectrum};
use coded_opt::metrics::TableWriter;

fn main() -> anyhow::Result<()> {
    let n = 120;
    let m = 16;
    let beta = 2.0;
    for (label, k) in [("small k (η=0.375, Fig. 5)", 6), ("large k (η=0.75, Fig. 6)", 12)] {
        println!("\n=== {label}: n={n}, m={m}, β≈{beta} ===");
        let mut table = TableWriter::new(&[
            "scheme", "n", "k/m", "β", "λmin", "λmax", "ε", "bulk@1",
        ]);
        for scheme in [
            Scheme::Gaussian,
            Scheme::Paley,
            Scheme::Hadamard,
            Scheme::Steiner,
            Scheme::Haar,
        ] {
            let enc = EncodingOp::build(scheme, n, m, beta, 5)?;
            let mut an = SubsetSpectrum::new(&enc, 11);
            let stats = an.analyze(k, 12);
            table.row(&stats.summary_row());
        }
        table.print();
    }
    println!("\nPaper's Figs. 5–6 shape: ETFs concentrate the bulk at exactly 1");
    println!("(Prop. 8 plateau); Gaussian spreads Marchenko–Pastur-style.");
    Ok(())
}
