//! Figure-14-style LASSO sparsity recovery: F1 score of the recovered
//! support over (simulated) time, for uncoded k=m, uncoded k<m,
//! replication, and Steiner-coded k<m under the trimodal delay mixture.
//! Each variant is one [`Experiment`](coded_opt::driver::Experiment)
//! running the [`Prox`] solver.
//!
//!     cargo run --release --example lasso_sparse_recovery

use coded_opt::config::Scheme;
use coded_opt::data::synth::sparse_recovery;
use coded_opt::delay::MixtureDelay;
use coded_opt::driver::{Experiment, Problem, Prox};
use coded_opt::metrics::f1_support;
use coded_opt::objectives::LassoProblem;

fn main() -> anyhow::Result<()> {
    // paper: X ∈ R^{130000×100000}, 7695-sparse w*, σ=40, λ=0.6, m=128,
    // k ∈ {80, 128} — scaled to simulator size preserving the ratios.
    let (n, p, nnz) = (1040, 800, 62);
    let (m, k_partial) = (16, 10); // k/m = 0.625 ≈ paper's 80/128
    let sigma = 0.5;
    let lambda = 0.05;
    let (x, y, w_star) = sparse_recovery(n, p, nnz, sigma, 31);
    let prob = LassoProblem::new(x.clone(), y.clone(), lambda);
    let step = prob.default_step();
    println!("LASSO (Fig. 14 shape, scaled): n={n} p={p} ‖w*‖₀={nnz} m={m}");
    println!("{:<22} {:>6} {:>8} {:>10} {:>12}", "scheme", "k", "F1", "objective", "sim time");

    let runs: Vec<(&str, Scheme, usize)> = vec![
        ("uncoded (k=m)", Scheme::Uncoded, m),
        ("uncoded (k<m)", Scheme::Uncoded, k_partial),
        ("replication (k<m)", Scheme::Replication, k_partial),
        ("steiner (k<m)", Scheme::Steiner, k_partial),
    ];
    for (label, scheme, k) in runs {
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(scheme)
            .workers(m)
            .wait_for(k)
            .redundancy(2.0)
            .seed(7)
            .delay(|m| Box::new(MixtureDelay::paper_trimodal(m, 23)))
            // delay-dominated regime, as on EC2: per-row compute ≪ stragglers
            .timing(2e-4, 1e-3)
            .label(label)
            .eval(|w| {
                let (_, _, f1) = f1_support(&w_star, w, 1e-2);
                (prob.objective(w), f1)
            })
            .run(Prox::with_step(step).lambda(lambda).iters(300))?;
        println!(
            "{:<22} {:>6} {:>8.3} {:>10.4} {:>10.1}s",
            label,
            k,
            out.trace.final_test_metric(),
            out.trace.final_objective(),
            out.trace.total_time()
        );
    }
    println!("\nExpected shape (paper Fig. 14): steiner k<m matches uncoded k=m recovery");
    println!("at a fraction of the time; uncoded k<m loses F1; k=m pays straggler time.");
    Ok(())
}
