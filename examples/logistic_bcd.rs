//! Figure-10/11-style logistic regression with encoded block coordinate
//! descent (model parallelism) vs the asynchronous baseline, under
//! power-law background-task stragglers. The encoded runs and the async
//! baseline all go through the same
//! [`Experiment`](coded_opt::driver::Experiment) driver — only the
//! solver differs.
//!
//!     cargo run --release --example logistic_bcd

use coded_opt::config::Scheme;
use coded_opt::data::rcv1like;
use coded_opt::delay::BackgroundTasksDelay;
use coded_opt::driver::{AsyncBcd, Bcd, Experiment, Problem};
use coded_opt::objectives::LogisticProblem;

fn main() -> anyhow::Result<()> {
    // paper: rcv1, 697641 docs × 32500 kept features, m=128, k=80, β=2 —
    // scaled; same power-law(α=1.5, cap 50) background-task stragglers.
    let (docs, feats, nnz) = (700, 256, 12);
    let (m, k) = (16, 10); // k/m = 0.625 = paper's 80/128
    let ds = rcv1like::generate(docs, feats, nnz, 0.05, 77);
    let x = ds.train.to_dense();
    let n_train = ds.train.rows();
    let prob = LogisticProblem::new(ds.train.clone(), 1e-4);
    let f0 = prob.objective(&vec![0.0; feats]);
    println!("logistic BCD (Fig. 10/11 shape): {n_train} docs × {feats} features, m={m} k={k}");
    println!("f(0) = {f0:.4}\n");
    let step = 1.0 / prob.smoothness() / 4.0;

    // ---- encoded BCD runs
    println!(
        "{:<18} {:>12} {:>10} {:>12} {:>12}",
        "scheme", "train obj", "test err", "sim time", "imbalance"
    );
    for scheme in [Scheme::Steiner, Scheme::Haar, Scheme::Uncoded] {
        let out = Experiment::new(Problem::logistic(&x))
            .scheme(scheme)
            .workers(m)
            .wait_for(k)
            .redundancy(2.0)
            .seed(13)
            .delay(|m| Box::new(BackgroundTasksDelay::new(m, 1.5, 50, 0.05, 29)))
            // delay-dominated regime (paper §5.3: background tasks dominate)
            .timing(1e-4, 1e-3)
            .label(scheme.name())
            .eval(|w| (prob.objective(w), prob.error_rate(w, &ds.test)))
            .run(Bcd::with_step(step).lambda(1e-4).iters(300))?;
        println!(
            "{:<18} {:>12.4} {:>10.3} {:>10.1}s {:>12.3}",
            scheme.name(),
            out.trace.final_objective(),
            out.trace.final_test_metric(),
            out.trace.total_time(),
            out.participation.imbalance()
        );
    }

    // ---- async baseline (Fig. 13's skewed participation): same driver,
    // different solver — uncoded column blocks, no rounds, no encoding.
    let out = Experiment::new(Problem::logistic(&x))
        .workers(m)
        .delay(|m| Box::new(BackgroundTasksDelay::new(m, 1.5, 50, 0.05, 29)))
        .timing(1e-4, 1e-3)
        .label("async")
        .eval(|w| (prob.objective(w), prob.error_rate(w, &ds.test)))
        .run(AsyncBcd::with_step(step).lambda(1e-4).updates(300 * k).record_every(60))?;
    println!(
        "{:<18} {:>12.4} {:>10.3} {:>10.1}s {:>12.3}",
        "async (uncoded)",
        out.trace.final_objective(),
        out.trace.final_test_metric(),
        out.trace.total_time(),
        out.participation.imbalance()
    );
    println!("\nShape notes (paper Figs. 10–13): the async baseline's participation is");
    println!("heavily skewed (imbalance ≫ encoded) — slow nodes contribute rare, stale");
    println!("updates. The wall-time-budget comparison is in benches/fig10/fig11.");
    Ok(())
}
