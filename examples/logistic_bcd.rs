//! Figure-10/11-style logistic regression with encoded block coordinate
//! descent (model parallelism) vs the asynchronous baseline, under
//! power-law background-task stragglers.
//!
//!     cargo run --release --example logistic_bcd

use coded_opt::cluster::SimCluster;
use coded_opt::config::Scheme;
use coded_opt::coordinator::bcd::{build_model_parallel, logistic_phi, run_bcd, BcdConfig};
use coded_opt::coordinator::asynchronous::{run_async_bcd, AsyncBcdConfig};
use coded_opt::data::rcv1like;
use coded_opt::delay::BackgroundTasksDelay;
use coded_opt::encoding::partition_bounds;
use coded_opt::objectives::LogisticProblem;

fn main() -> anyhow::Result<()> {
    // paper: rcv1, 697641 docs × 32500 kept features, m=128, k=80, β=2 —
    // scaled; same power-law(α=1.5, cap 50) background-task stragglers.
    let (docs, feats, nnz) = (700, 256, 12);
    let (m, k) = (16, 10); // k/m = 0.625 = paper's 80/128
    let ds = rcv1like::generate(docs, feats, nnz, 0.05, 77);
    let x = ds.train.to_dense();
    let n_train = ds.train.rows();
    let prob = LogisticProblem::new(ds.train.clone(), 1e-4);
    let f0 = prob.objective(&vec![0.0; feats]);
    println!("logistic BCD (Fig. 10/11 shape): {n_train} docs × {feats} features, m={m} k={k}");
    println!("f(0) = {f0:.4}\n");
    let step = 1.0 / prob.smoothness() / 4.0;

    // ---- encoded BCD runs
    println!(
        "{:<18} {:>12} {:>10} {:>12} {:>12}",
        "scheme", "train obj", "test err", "sim time", "imbalance"
    );
    for scheme in [Scheme::Steiner, Scheme::Haar, Scheme::Uncoded] {
        let mp = build_model_parallel(&x, scheme, m, 2.0, step, 1e-4, 13, logistic_phi())?;
        let sbar = mp.sbar;
        let delay = BackgroundTasksDelay::new(m, 1.5, 50, 0.05, 29);
        // delay-dominated regime (paper §5.3: background tasks dominate)
        let mut cluster =
            SimCluster::new(mp.workers, Box::new(delay)).with_timing(1e-4, 1e-3);
        let cfg = BcdConfig { k, iters: 300 };
        let out = run_bcd(&mut cluster, &sbar, n_train, feats, &cfg, scheme.name(), &|w| {
            (prob.objective(w), prob.error_rate(w, &ds.test))
        });
        println!(
            "{:<18} {:>12.4} {:>10.3} {:>10.1}s {:>12.3}",
            scheme.name(),
            out.trace.final_objective(),
            out.trace.final_test_metric(),
            out.trace.total_time(),
            out.participation.imbalance()
        );
    }

    // ---- async baseline (Fig. 13's skewed participation)
    let bounds = partition_bounds(feats, m);
    let blocks: Vec<coded_opt::linalg::Mat> = bounds
        .windows(2)
        .map(|w| {
            let idx: Vec<usize> = (w[0]..w[1]).collect();
            x.select_cols(&idx)
        })
        .collect();
    let grad_phi = |u: &[f64]| -> Vec<f64> {
        let n = u.len() as f64;
        u.iter().map(|&ui| -coded_opt::objectives::logistic::sigmoid(-ui) / n).collect()
    };
    let mut delay = BackgroundTasksDelay::new(m, 1.5, 50, 0.05, 29);
    let cfg = AsyncBcdConfig {
        step,
        lambda: 1e-4,
        updates: 300 * k,
        secs_per_unit: 1e-4,
        record_every: 60,
    };
    let eval = |v: &[Vec<f64>]| -> (f64, f64) {
        let w: Vec<f64> = v.iter().flatten().copied().collect();
        (prob.objective(&w), prob.error_rate(&w, &ds.test))
    };
    let (trace, _, part) =
        run_async_bcd(&blocks, &grad_phi, n_train, &cfg, &mut delay, "async", &eval);
    println!(
        "{:<18} {:>12.4} {:>10.3} {:>10.1}s {:>12.3}",
        "async (uncoded)",
        trace.final_objective(),
        trace.final_test_metric(),
        trace.total_time(),
        part.imbalance()
    );
    println!("\nShape notes (paper Figs. 10–13): the async baseline's participation is");
    println!("heavily skewed (imbalance ≫ encoded) — slow nodes contribute rare, stale");
    println!("updates. The wall-time-budget comparison is in benches/fig10/fig11.");
    Ok(())
}
