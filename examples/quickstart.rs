//! Quickstart: encoded gradient descent on a ridge problem with
//! bimodal stragglers, in ~30 lines of library use.
//!
//!     cargo run --release --example quickstart
//!
//! Builds a Hadamard (β=2) encoding over 8 simulated workers, waits for
//! the fastest 6 each round, and prints the convergence trace on the
//! ORIGINAL objective — next to an uncoded baseline suffering the same
//! stragglers.

use coded_opt::cluster::SimCluster;
use coded_opt::config::Scheme;
use coded_opt::coordinator::{build_data_parallel, run_gd, GdConfig};
use coded_opt::data::synth::gaussian_linear;
use coded_opt::delay::MixtureDelay;
use coded_opt::objectives::{QuadObjective, RidgeProblem};

fn main() -> anyhow::Result<()> {
    let (n, p, m, k) = (512, 64, 8, 6);
    let (x, y, _) = gaussian_linear(n, p, 0.5, 42);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f_star = prob.objective(&prob.solve_exact());
    println!("ridge: n={n} p={p} m={m} k={k}   f* = {f_star:.6}");
    println!("{:<12} {:>10} {:>14} {:>12}", "scheme", "iters", "f(w_T)", "sim time");

    for scheme in [Scheme::Hadamard, Scheme::Uncoded] {
        let dp = build_data_parallel(&x, &y, scheme, m, 2.0, 42)?;
        let asm = dp.assembler.clone();
        // the paper's §5.3 bimodal delay: half the fleet ~0.5s, half ~20s
        let delay = MixtureDelay::paper_bimodal(m, 7);
        let mut cluster = SimCluster::new(dp.workers, Box::new(delay));
        let cfg = GdConfig {
            k,
            step: 1.0 / prob.smoothness(),
            iters: 200,
            lambda: 0.05,
            w0: None,
        };
        let out = run_gd(&mut cluster, &asm, &cfg, scheme.name(), &|w| {
            (prob.objective(w), 0.0)
        });
        println!(
            "{:<12} {:>10} {:>14.6} {:>10.1}s",
            scheme.name(),
            out.trace.len(),
            out.trace.final_objective(),
            out.trace.total_time()
        );
    }
    println!("\n(encoded run lands near f*; uncoded fixed-k is biased by dropped blocks)");
    Ok(())
}
