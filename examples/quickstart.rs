//! Quickstart: encoded gradient descent on a ridge problem with
//! bimodal stragglers, in a dozen lines of library use.
//!
//!     cargo run --release --example quickstart
//!
//! One [`Experiment`](coded_opt::driver::Experiment) describes the whole
//! pipeline — problem, encoding scheme, worker count, wait-for-k gather,
//! straggler delays, evaluation — and `.run(solver)` executes any
//! algorithm through it. Here: a Hadamard (β=2) encoding over 8
//! simulated workers, waiting for the fastest 6 each round, printing the
//! convergence trace on the ORIGINAL objective — next to an uncoded
//! baseline suffering the same stragglers.

use coded_opt::config::Scheme;
use coded_opt::data::synth::gaussian_linear;
use coded_opt::delay::MixtureDelay;
use coded_opt::driver::{Experiment, Gd, Problem};
use coded_opt::objectives::{QuadObjective, RidgeProblem};
use coded_opt::scenario::Scenario;

fn main() -> anyhow::Result<()> {
    let (n, p, m, k) = (512, 64, 8, 6);
    let (x, y, _) = gaussian_linear(n, p, 0.5, 42);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f_star = prob.objective(&prob.solve_exact());
    println!("ridge: n={n} p={p} m={m} k={k}   f* = {f_star:.6}");
    println!("{:<12} {:>10} {:>14} {:>12}", "scheme", "iters", "f(w_T)", "sim time");

    for scheme in [Scheme::Hadamard, Scheme::Uncoded] {
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(scheme)
            .workers(m)
            .wait_for(k)
            .redundancy(2.0)
            .seed(42)
            // the paper's §5.3 bimodal delay: half the fleet ~0.5s, half ~20s
            .delay(|m| Box::new(MixtureDelay::paper_bimodal(m, 7)))
            .label(scheme.name())
            .eval(|w| (prob.objective(w), 0.0))
            .run(Gd::with_step(1.0 / prob.smoothness()).lambda(0.05).iters(200))?;
        println!(
            "{:<12} {:>10} {:>14.6} {:>10.1}s",
            scheme.name(),
            out.trace.len(),
            out.trace.final_objective(),
            out.trace.total_time()
        );
    }
    println!("\n(encoded run lands near f*; uncoded fixed-k is biased by dropped blocks)");

    // Scenario engine: the same pipeline under an adversarial
    // crash/rejoin pattern — a quarter of the fleet dies for rounds
    // [5, 15) and comes back. A crash is just an unbounded delay, so the
    // wait-for-k gather erases the dead nodes exactly like any other
    // straggler (no new coordinator logic), and the encoding's
    // redundancy covers the lost updates. Scenarios are named, seeded,
    // and also loadable from TOML — see the coded_opt::scenario docs.
    let sc = Scenario::builtin("crash-rejoin").expect("builtin scenario");
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Hadamard)
        .workers(m)
        .wait_for(k)
        .redundancy(2.0)
        .seed(42)
        .scenario(&sc)
        .label("crash-rejoin")
        .eval(|w| (prob.objective(w), 0.0))
        .run(Gd::with_step(1.0 / prob.smoothness()).lambda(0.05).iters(200))?;
    println!(
        "\nscenario '{}': f(w_T) = {:.6} after {:.1}s — deterministic sample-path \
         convergence under crash/rejoin (Theorem 2's arbitrary-A_t claim)",
        sc.name,
        out.trace.final_objective(),
        out.trace.total_time()
    );

    // Adaptive wait-for-k: instead of a fixed k, an online controller
    // (coded_opt::control) watches each round's arrival times and moves
    // the NEXT round's k within hard bounds — never below the erasure
    // floor ceil(m/β) the encoding can absorb, never above the live
    // worker count. Decisions derive only from recorded arrivals, so a
    // replayed delay tape reproduces every k decision bit-for-bit and
    // the adaptive golden fixtures pin the whole decision sequence.
    // Controller-steered runs carry a per-round log in RunOutput
    // (requested/effective k, live count, winner arrival times), also
    // emitted by `coded-opt run --policy adaptive --trace-out`.
    use coded_opt::control::KPolicy;
    let steered = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Hadamard)
        .workers(m)
        .wait_for(k)
        .redundancy(2.0)
        .seed(42)
        .scenario(&sc)
        .controller(KPolicy::parse("adaptive")?)
        .label("adaptive")
        .eval(|w| (prob.objective(w), 0.0))
        .run(Gd::with_step(1.0 / prob.smoothness()).lambda(0.05).iters(200))?;
    let (k_lo, k_hi) = steered
        .rounds
        .iter()
        .fold((m, 0), |(lo, hi), r| (lo.min(r.k_effective), hi.max(r.k_effective)));
    println!(
        "controller '{}': effective k ranged {k_lo}..{k_hi} over {} rounds of '{}'",
        steered.controller,
        steered.rounds.len(),
        sc.name
    );
    // The redundancy/latency trade-off those knobs span is a standing
    // artifact, not an ad-hoc figure: `coded-opt pareto` sweeps
    // (scheme, β, k-policy) × scenario, attaches the erasure-robustness
    // coordinate (m − ceil(m/β))/m to each cell's time-to-ε, prunes
    // per-scenario dominated points, and writes a `coded-opt/pareto-v1`
    // report (per-cell rows use the same metrics as `coded-opt scenario
    // --json-out`, schema `coded-opt/grid-v1`). CI's pareto-smoke job
    // runs a pinned-seed sweep twice and byte-compares the reports.

    // Compute-kernel threading: the linalg kernels run on a
    // deterministic chunk pool (coded_opt::linalg::par). Results are
    // BIT-IDENTICAL at any thread count — the knob only trades
    // wall-clock for cores — so cranking it cannot move a trace. It is
    // process-global: set it via `Experiment::threads(n)`, by calling
    // `coded_opt::linalg::par::set_threads`, or with the
    // CODED_OPT_THREADS environment variable. Kernel timings
    // live in `coded-opt bench` (BENCH_hotpath.json, schema
    // `coded-opt/bench-v1` — see coded_opt::bench), which CI gates
    // against bench/baseline.json.
    let eight = Experiment::new(Problem::least_squares(&x, &y))
        .workers(m)
        .wait_for(k)
        .seed(42)
        .threads(8)
        .eval(|w| (prob.objective(w), 0.0))
        .run(Gd::with_step(1.0 / prob.smoothness()).lambda(0.05).iters(50))?;
    let one = Experiment::new(Problem::least_squares(&x, &y))
        .workers(m)
        .wait_for(k)
        .seed(42)
        .threads(1)
        .eval(|w| (prob.objective(w), 0.0))
        .run(Gd::with_step(1.0 / prob.smoothness()).lambda(0.05).iters(50))?;
    assert_eq!(one.w, eight.w, "kernel threading must never move a result");
    println!("\nthreads=1 and threads=8 runs are bit-identical (deterministic chunk pool)");

    // The same is true of SIMD: with AVX2 the kernels run std::arch
    // fast paths, but they vectorize across independent outputs in the
    // scalar accumulation order, so results stay bit-identical and the
    // CODED_OPT_SIMD toggle (0 = force scalar) is pure speed — see the
    // coded_opt::linalg::simd docs. Mixed precision is the one knob
    // that ISN'T bit-pinned: `.precision(Precision::F32)` stores worker
    // shards at f32 (half the memory/bandwidth) while accumulating in
    // f64. Each kernel stays within 1e-5 of the f64 referee; over a
    // whole run the rounding compounds, so compare loosely:
    use coded_opt::linalg::Precision;
    let half = Experiment::new(Problem::least_squares(&x, &y))
        .workers(m)
        .wait_for(k)
        .seed(42)
        .precision(Precision::F32)
        .eval(|w| (prob.objective(w), 0.0))
        .run(Gd::with_step(1.0 / prob.smoothness()).lambda(0.05).iters(50))?;
    let drift = one
        .w
        .iter()
        .zip(&half.w)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(drift < 1e-3, "f32 shard storage drifted too far: {drift:e}");
    println!("f32-shard run tracks the f64 run (max |Δw| = {drift:.1e}, shards at half size)");

    // Out-of-core: the same experiment can read its dataset from a
    // shard directory instead of memory. A sharded dataset is a
    // manifest.json (schema `coded-opt/shard-v1`: rows/cols, targets
    // flag, per-shard file + row range + checksum) plus shard-*.bin
    // row blocks; the encoded worker partitions are then assembled
    // block-by-block (coded_opt::encoding::stream) and the resulting
    // trace is BIT-IDENTICAL to the in-memory run — the streaming
    // encoders continue the exact floating-point accumulation order of
    // the dense kernels. CLI mirror: `coded-opt shard` / `coded-opt
    // encode` / `coded-opt run --source DIR`.
    let dir = std::env::temp_dir().join(format!("coded-opt-quickstart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    coded_opt::data::shard::shard_dataset(&x, Some(&y), &dir, 64)?;
    let src = coded_opt::data::ShardedSource::open(&dir)?;
    let sharded = Experiment::sharded(src)
        .workers(m)
        .wait_for(k)
        .seed(42)
        .eval(|w| (prob.objective(w), 0.0))
        .run(Gd::with_step(1.0 / prob.smoothness()).lambda(0.05).iters(50))?;
    assert_eq!(one.w, sharded.w, "sharded and in-memory runs must agree bit-for-bit");
    println!("sharded-source run is bit-identical to the in-memory run (8 shards of 64 rows)");
    let _ = std::fs::remove_dir_all(&dir);

    // Operator-first encoding: a scheme is a SchemeSpec (a handful of
    // integers) that lowers to a lazy EncodingOp — apply/apply_t run
    // through FWHT or CSR structure, and row_block(i) produces a
    // worker's S_i on demand. No dense row of S is stored anywhere:
    // structured schemes (hadamard/steiner/haar/identity) never
    // materialize a dense block on any encode path, and the dense
    // ensembles (gaussian/paley) regenerate each block from the seed
    // per use and drop it after (bit-identical across calls). The
    // coded_opt::encoding::probe counters make the claim checkable:
    use coded_opt::encoding::{probe, Encoder, SchemeSpec};
    probe::reset();
    let op = SchemeSpec::new(Scheme::Hadamard, p, m, 2.0, 42).lower()?;
    let w_demo: Vec<f64> = (0..p).map(|i| 0.1 * i as f64).collect();
    let encoded = op.apply(&w_demo); // S·w through FWHT, O(N log N)
    let back = op.apply_t(&encoded); // Sᵀ(S·w) = β·w (tight frame)
    assert!((back[3] / op.beta - w_demo[3]).abs() < 1e-9);
    assert_eq!(probe::dense_bytes(), 0, "structured encode stays dense-free");
    println!(
        "operator-first encoding: S is {}x{} (β={:.2}) yet zero dense generator \
         bytes were materialized",
        op.total_rows(),
        op.n,
        op.beta
    );

    // All of the bit-identity claims above rest on source-level
    // invariants (total float orders, no wall-clock reads in simulated
    // paths, ordered iteration, audited unsafe) plus architecture-level
    // ones checked on the extracted module graph (the layering DAG,
    // zone containment, no eager buffers in streaming modules). They
    // are mechanized as `coded-opt lint` — the determinism-contract
    // static analysis (coded_opt::analysis), blocking in CI. Run it
    // locally with `cargo run --release -- lint` (`--format json` for
    // the `coded-opt/lint-v1` report, `--format github` for PR-diff
    // annotations, `--graph-out FILE` for the module DAG CI keeps
    // committed as `module-graph.json`); exceptions need an inline
    // `lint:allow(<rule>)` with a justification, which the report counts.
    Ok(())
}
