//! END-TO-END DRIVER — exercises every layer of the stack on a real
//! workload (EXPERIMENTS.md records a run):
//!
//!   L1 Pallas kernel  → lowered inside →  L2 JAX quad_grad  →
//!   AOT HLO artifact  → compiled by    →  rust PJRT runtime →
//!   executed by       → thread-cluster workers under injected
//!   bimodal stragglers, coordinated by → encoded L-BFGS (L3).
//!
//!     make artifacts && cargo run --release --example end_to_end
//!
//! The whole pipeline is one [`Experiment`](coded_opt::driver::Experiment)
//! on the [`Engine::Threads`] engine with the AOT runtime attached.
//! Trains ridge regression (512 train rows × 64 features, β=2 over 8
//! workers → 128×64-shaped worker shards matching the shipped
//! `quad_grad_128x64` artifact), logs the loss curve, and reports PJRT
//! usage + timing.

use coded_opt::config::Scheme;
use coded_opt::data::synth::{gaussian_linear, split_rows, take_rows};
use coded_opt::delay::MixtureDelay;
use coded_opt::driver::{Engine, Experiment, Lbfgs, Problem};
use coded_opt::metrics::write_csv;
use coded_opt::objectives::{QuadObjective, RidgeProblem};
use coded_opt::runtime::ArtifactIndex;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    // ---- data: 640 samples, 64 features, 80/20 split
    let (x_all, y_all, _) = gaussian_linear(640, 64, 0.5, 2024);
    let (train_idx, test_idx) = split_rows(640, 0.2, 7);
    let (x, y) = take_rows(&x_all, &y_all, &train_idx);
    let (x_test, y_test) = take_rows(&x_all, &y_all, &test_idx);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f_star = prob.objective(&prob.solve_exact());

    // ---- encoded workers with the AOT runtime attached
    let (m, k, beta) = (8usize, 6usize, 2.0);
    let idx = ArtifactIndex::load(Path::new("artifacts"))?;
    anyhow::ensure!(!idx.is_empty(), "run `make artifacts` first");
    // Pre-flight, equivalent to the shape-match attach check the worker
    // build performs per shard (PJRT compilation itself is lazy, on
    // first gradient): all 8 shards are 128×64, so one index lookup
    // covers them. The post-run `pjrt_attached == m` assert below then
    // confirms the attach actually happened.
    anyhow::ensure!(
        idx.find("quad_grad", 128, 64).is_some(),
        "artifacts are stale: no quad_grad 128x64 module (re-run `make artifacts`)"
    );

    // ---- one Experiment: encoded data-parallel shards on a real thread
    // cluster, paper's bimodal stragglers (scaled 1s→1ms), PJRT runtime.
    // 512 train rows × β=2 → 1024 encoded rows → 8 shards of 128×64:
    // matches the shipped quad_grad_128x64 artifact exactly.
    let t0 = std::time::Instant::now();
    let out = Experiment::new(Problem::least_squares(&x, &y))
        .scheme(Scheme::Hadamard)
        .workers(m)
        .wait_for(k)
        .redundancy(beta)
        .seed(11)
        .runtime(&idx)
        .engine(Engine::Threads { delay_scale: 1e-3 })
        .delay(|m| Box::new(MixtureDelay::paper_bimodal(m, 3)))
        .label("e2e-lbfgs")
        .eval(|w| (prob.objective(w), prob.test_mse(w, &x_test, &y_test)))
        .run(Lbfgs::new().iters(60).lambda(0.05))?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "workers: {m}  (PJRT-backed: {}/{m})  scheme=hadamard β={beta}  k={k}",
        out.pjrt_attached
    );
    anyhow::ensure!(out.pjrt_attached == m, "expected all shards on the AOT path");

    // ---- loss curve
    println!("\n iter    f(w_t)          (f-f*)/f*      test MSE");
    for r in out.trace.records.iter().step_by(5) {
        println!(
            "{:>5}   {:<14.8} {:<14.3e} {:<10.5}",
            r.iter,
            r.objective,
            (r.objective - f_star) / f_star,
            r.test_metric
        );
    }
    let last = out.trace.records.last().unwrap();
    println!(
        "{:>5}   {:<14.8} {:<14.3e} {:<10.5}",
        last.iter,
        last.objective,
        (last.objective - f_star) / f_star,
        last.test_metric
    );
    println!("\nf*            = {f_star:.8}");
    println!("final subopt  = {:.3e}", (last.objective - f_star) / f_star);
    println!(
        "wall time     = {wall:.2}s total (encode + PJRT compile + {} iterations)",
        out.trace.len()
    );
    // ThreadCluster's clock starts after the shards are built, so the
    // trace's total time measures the solve loop itself.
    println!(
        "throughput    = {:.1} gather-rounds/s over {m} threaded workers",
        2.0 * out.trace.len() as f64 / out.trace.total_time()
    );
    write_csv(Path::new("out/end_to_end_trace.csv"), &[&out.trace])?;
    println!("trace written to out/end_to_end_trace.csv");
    // Data-parallel encoding with k < m converges to a κ-neighborhood of
    // f* (Theorem 4), floored additionally by the f32 artifacts; ~2e-3
    // relative is the expected band at this operating point.
    anyhow::ensure!((last.objective - f_star) / f_star < 1e-2, "did not converge");
    println!("\nEND-TO-END OK: L1 pallas → L2 jax → AOT HLO → PJRT → L3 coordinator");
    Ok(())
}
