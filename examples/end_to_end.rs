//! END-TO-END DRIVER — exercises every layer of the stack on a real
//! workload (EXPERIMENTS.md records a run):
//!
//!   L1 Pallas kernel  → lowered inside →  L2 JAX quad_grad  →
//!   AOT HLO artifact  → compiled by    →  rust PJRT runtime →
//!   executed by       → thread-cluster workers under injected
//!   bimodal stragglers, coordinated by → encoded L-BFGS (L3).
//!
//!     make artifacts && cargo run --release --example end_to_end
//!
//! Trains ridge regression (n=512, p=128 → 128×64-shaped worker shards
//! matching the shipped `quad_grad_128x64` artifact), logs the loss
//! curve, and reports PJRT usage + timing.

use coded_opt::cluster::ThreadCluster;
use coded_opt::config::Scheme;
use coded_opt::coordinator::{build_data_parallel_with_runtime, run_lbfgs, LbfgsConfig};
use coded_opt::data::synth::{gaussian_linear, split_rows, take_rows};
use coded_opt::delay::MixtureDelay;
use coded_opt::metrics::write_csv;
use coded_opt::objectives::{QuadObjective, RidgeProblem};
use coded_opt::runtime::ArtifactIndex;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    // ---- data: 640 samples, 64 features, 80/20 split
    let (x_all, y_all, _) = gaussian_linear(640, 64, 0.5, 2024);
    let (train_idx, test_idx) = split_rows(640, 0.2, 7);
    let (x, y) = take_rows(&x_all, &y_all, &train_idx);
    let (x_test, y_test) = take_rows(&x_all, &y_all, &test_idx);
    let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
    let f_star = prob.objective(&prob.solve_exact());

    // ---- encoded workers with the AOT runtime attached
    let (m, k, beta) = (8usize, 6usize, 2.0);
    let idx = ArtifactIndex::load(Path::new("artifacts"))?;
    anyhow::ensure!(!idx.is_empty(), "run `make artifacts` first");
    // 512 train rows × β=2 → 1024 encoded rows → 8 shards of 128×64:
    // matches the shipped quad_grad_128x64 artifact exactly.
    let dp = build_data_parallel_with_runtime(&x, &y, Scheme::Hadamard, m, beta, 11, Some(&idx))?;
    println!(
        "workers: {m}  (PJRT-backed: {}/{m})  scheme=hadamard β={beta}  k={k}",
        dp.pjrt_attached
    );
    anyhow::ensure!(dp.pjrt_attached == m, "expected all shards on the AOT path");
    let asm = dp.assembler.clone();

    // ---- real thread cluster, paper's bimodal stragglers (scaled 1s→1ms)
    let delay = MixtureDelay::paper_bimodal(m, 3);
    let mut cluster = ThreadCluster::new(dp.workers, Box::new(delay)).with_delay_scale(1e-3);

    // ---- encoded L-BFGS
    let cfg = LbfgsConfig { k, iters: 60, lambda: 0.05, memory: 10, rho: 0.9, w0: None };
    let t0 = std::time::Instant::now();
    let out = run_lbfgs(&mut cluster, &asm, &cfg, "e2e-lbfgs", &|w| {
        (prob.objective(w), prob.test_mse(w, &x_test, &y_test))
    });
    let wall = t0.elapsed().as_secs_f64();

    // ---- loss curve
    println!("\n iter    f(w_t)          (f-f*)/f*      test MSE");
    for r in out.trace.records.iter().step_by(5) {
        println!(
            "{:>5}   {:<14.8} {:<14.3e} {:<10.5}",
            r.iter,
            r.objective,
            (r.objective - f_star) / f_star,
            r.test_metric
        );
    }
    let last = out.trace.records.last().unwrap();
    println!(
        "{:>5}   {:<14.8} {:<14.3e} {:<10.5}",
        last.iter,
        last.objective,
        (last.objective - f_star) / f_star,
        last.test_metric
    );
    println!("\nf*            = {f_star:.8}");
    println!("final subopt  = {:.3e}", (last.objective - f_star) / f_star);
    println!("wall time     = {wall:.2}s for {} iterations (2 rounds each)", out.trace.len());
    println!(
        "throughput    = {:.1} gather-rounds/s over {m} threaded workers",
        2.0 * out.trace.len() as f64 / wall
    );
    write_csv(Path::new("out/end_to_end_trace.csv"), &[&out.trace])?;
    println!("trace written to out/end_to_end_trace.csv");
    // Data-parallel encoding with k < m converges to a κ-neighborhood of
    // f* (Theorem 4), floored additionally by the f32 artifacts; ~2e-3
    // relative is the expected band at this operating point.
    anyhow::ensure!((last.objective - f_star) / f_star < 1e-2, "did not converge");
    println!("\nEND-TO-END OK: L1 pallas → L2 jax → AOT HLO → PJRT → L3 coordinator");
    Ok(())
}
