//! OUT-OF-CORE PIPELINE — the whole sharded path with no full-matrix
//! materialization anywhere: generate a dataset straight into the
//! shard-v1 format (one shard buffer resident at a time), open it
//! (reads only the manifest), solve with encoded L-BFGS streaming
//! blocks through the encoder, and evaluate BOTH the per-iteration
//! loss curve and the final iterate with
//! [`ShardedSource::half_mse`](coded_opt::data::ShardedSource::half_mse)
//! — the one-pass streamed objective. Nothing in this file ever holds
//! `X`; peak resident data is one `shard_rows × p` block.
//!
//!     cargo run --release --example sharded_streaming

use coded_opt::config::Scheme;
use coded_opt::data::synth::gaussian_linear_shard_to;
use coded_opt::data::ShardedSource;
use coded_opt::driver::{Experiment, Lbfgs};
use coded_opt::linalg::norm2;

fn main() -> anyhow::Result<()> {
    // 2048 × 128 in 8 shards of 256 rows. β=2 over 8 workers gives
    // 4096 encoded rows → power-of-two FWHT, 512-row worker shards.
    let (n, p, shard_rows) = (2048usize, 128usize, 256usize);
    let dir = std::env::temp_dir().join(format!("coded-opt-sharded-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let (manifest, w_star) = gaussian_linear_shard_to(&dir, n, p, 0.5, 321, shard_rows)?;
    println!(
        "dataset: {} rows × {} cols in {} shards under {}",
        manifest.rows,
        manifest.cols,
        manifest.shards.len(),
        dir.display()
    );

    // The eval closure streams too: ½·mean‖Xw−y‖² one shard at a time,
    // re-reading (and checksum-verifying) the shards on every call.
    let source = ShardedSource::open(&dir)?;
    let eval_src = source.clone();
    let out = Experiment::sharded(source.clone())
        .scheme(Scheme::Hadamard)
        .workers(8)
        .wait_for(6)
        .redundancy(2.0)
        .seed(9)
        .label("sharded-lbfgs")
        .eval(move |w| (eval_src.half_mse(w).expect("streamed objective"), 0.0))
        .run(Lbfgs::new().iters(40))?;

    println!("\n iter    f(w_t)  [streamed ½·MSE]");
    for r in out.trace.records.iter().step_by(8) {
        println!("{:>5}   {:<14.8}", r.iter, r.objective);
    }

    // Final-iterate checks, both streamed: the data term again, and
    // recovery error against the generator's planted w*.
    let final_obj = source.half_mse(&out.w)?;
    let mut diff = out.w.clone();
    for (d, t) in diff.iter_mut().zip(&w_star) {
        *d -= t;
    }
    let rel = norm2(&diff) / norm2(&w_star);
    println!("\nfinal streamed ½·MSE: {final_obj:.6}");
    println!("‖w − w*‖/‖w*‖ = {rel:.3e}  (σ=0.5 noise keeps this above zero)");
    anyhow::ensure!(rel < 0.5, "L-BFGS failed to approach the planted model: {rel:e}");

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
