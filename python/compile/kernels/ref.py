"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the L1 kernels are validated against in
``python/tests/``; they are also lowered (without Pallas) as alternative
artifacts so the rust integration test can cross-check numerics.
"""

import jax.numpy as jnp


def encoded_grad_ref(sx, sy, w):
    """r = sxᵀ(sx·w − sy)."""
    return sx.T @ (sx @ w - sy)


def linesearch_quad_ref(sx, d):
    """‖sx·d‖² — the worker's exact-line-search response (paper eq. 3)."""
    v = sx @ d
    return jnp.dot(v, v)


def soft_threshold_ref(x, tau):
    """prox of τ‖·‖₁ (ISTA master step)."""
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - tau, 0.0)
