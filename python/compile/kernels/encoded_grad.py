"""L1 Pallas kernel: the encoded-gradient hot spot.

Computes the per-worker partial gradient of the encoded quadratic loss
(paper eq. 10):

    r = (S̄X)ᵀ (S̄X·w − S̄y)

for the worker's resident shard ``sx = S̄X ∈ R^{rows×p}``, ``sy = S̄y``.
The kernel tiles the shard over a 1-D grid of row-blocks: each grid step
streams one ``(block_rows × p)`` tile of ``sx`` through VMEM while ``w``
stays resident, computes the local residual, and accumulates the
rank-`block_rows` contribution into the output block (which maps to the
same ``p``-vector at every grid step — the canonical Pallas reduction
pattern).

TPU mapping (DESIGN.md §4): the two products per tile are MXU-shaped
matmuls (``tile @ w`` and ``tileᵀ @ resid``); VMEM footprint per step is
``block_rows·p + 2·block_rows + 2·p`` floats. On this CPU plugin the
kernel runs with ``interpret=True`` (Mosaic custom-calls cannot execute
on CPU-PJRT); the lowered HLO is what the rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_ROWS = 128


def _grad_kernel(sx_ref, sy_ref, w_ref, o_ref):
    """One grid step: accumulate sx_tileᵀ(sx_tile·w − sy_tile)."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tile = sx_ref[...]  # (block_rows, p)
    resid = tile @ w_ref[...] - sy_ref[...]  # (block_rows,)
    o_ref[...] += tile.T @ resid  # (p,)


def _pick_block_rows(rows: int, requested: int) -> int:
    """Largest divisor of ``rows`` not exceeding ``requested``."""
    b = min(requested, rows)
    while rows % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_rows",))
def encoded_grad(sx, sy, w, *, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Pallas-tiled encoded gradient ``sxᵀ(sx·w − sy)``.

    Shapes: ``sx (rows, p)``, ``sy (rows,)``, ``w (p,)`` → ``(p,)``.
    """
    rows, p = sx.shape
    assert sy.shape == (rows,), f"sy shape {sy.shape} != ({rows},)"
    assert w.shape == (p,), f"w shape {w.shape} != ({p},)"
    b = _pick_block_rows(rows, block_rows)
    grid = (rows // b,)
    return pl.pallas_call(
        _grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, p), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((p,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((p,), sx.dtype),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(sx, sy, w)


def vmem_estimate_bytes(block_rows: int, p: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint of one grid step (DESIGN.md §Perf):
    sx tile + sy tile + w + output accumulator + residual scratch."""
    return dtype_bytes * (block_rows * p + block_rows + p + p + block_rows)
