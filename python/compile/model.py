"""L2 JAX model: the per-worker computations, built on the L1 kernels.

Each function here is a pure JAX function over the worker's resident
shard; ``aot.py`` lowers them (per shard shape) to HLO text, and the
rust runtime (rust/src/runtime) executes the artifacts from the hot
path. The Pallas kernel is called inside, so it lowers into the same
HLO module — a single PJRT call per worker step.
"""

import jax.numpy as jnp

from .kernels.encoded_grad import encoded_grad
from .kernels import ref


def quad_grad(sx, sy, w):
    """Worker gradient task (KIND_GRADIENT): r = (S̄X)ᵀ(S̄X·w − S̄y).

    The matmul hot spot runs through the Pallas kernel; returns a tuple
    so the rust side unwraps with ``to_tuple1``.
    """
    return (encoded_grad(sx, sy, w),)


def quad_grad_jnp(sx, sy, w):
    """Reference variant without Pallas (cross-check artifact)."""
    return (ref.encoded_grad_ref(sx, sy, w),)


def linesearch_quad(sx, d):
    """Worker line-search task (KIND_LINESEARCH): ‖S̄X·d‖² (eq. 3)."""
    v = sx @ d
    return (jnp.dot(v, v),)


def prox_step(w, g, alpha, tau):
    """Master-side ISTA step (lowered for completeness / future fusing):
    prox_{τ‖·‖₁}(w − α·g)."""
    z = w - alpha * g
    return (jnp.sign(z) * jnp.maximum(jnp.abs(z) - tau, 0.0),)
