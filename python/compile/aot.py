"""AOT lowering: JAX/Pallas (L2+L1) → HLO text artifacts for the rust
runtime.

Usage: ``cd python && python -m compile.aot --out ../artifacts``

Emits one ``<name>.hlo.txt`` per (function, shard shape) variant plus
``manifest.toml``, the index the rust `ArtifactIndex` loads.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects; the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Shard shapes (rows, cols) the benches/examples use. A worker whose
# shard matches one of these gets the PJRT fast path; anything else
# falls back to the rust-native kernel.
QUAD_GRAD_SHAPES = [
    (64, 32),
    (128, 64),
    (256, 64),
    (256, 128),
    (512, 128),
]

LINESEARCH_SHAPES = [
    (128, 64),
    (256, 128),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    rust side can unwrap uniformly with ``to_tuple1``)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_quad_grad(rows: int, cols: int, use_pallas: bool = True) -> str:
    sx = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
    sy = jax.ShapeDtypeStruct((rows,), jnp.float32)
    w = jax.ShapeDtypeStruct((cols,), jnp.float32)
    fn = model.quad_grad if use_pallas else model.quad_grad_jnp
    return to_hlo_text(jax.jit(fn).lower(sx, sy, w))


def lower_linesearch(rows: int, cols: int) -> str:
    sx = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
    d = jax.ShapeDtypeStruct((cols,), jnp.float32)
    return to_hlo_text(jax.jit(model.linesearch_quad).lower(sx, d))


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest_lines = []

    def emit(name: str, kind: str, rows: int, cols: int, text: str) -> None:
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest_lines.append(
            f'[{name}]\nfile = "{fname}"\nkind = "{kind}"\nrows = {rows}\ncols = {cols}\n'
        )
        print(f"  {name}: {len(text)} chars")

    print("lowering quad_grad (pallas) variants:")
    for rows, cols in QUAD_GRAD_SHAPES:
        emit(
            f"quad_grad_{rows}x{cols}",
            "quad_grad",
            rows,
            cols,
            lower_quad_grad(rows, cols, use_pallas=True),
        )

    print("lowering quad_grad (jnp reference) cross-check variant:")
    rows, cols = QUAD_GRAD_SHAPES[0]
    emit(
        f"quad_grad_jnp_{rows}x{cols}",
        "quad_grad_jnp",
        rows,
        cols,
        lower_quad_grad(rows, cols, use_pallas=False),
    )

    print("lowering linesearch variants:")
    for rows, cols in LINESEARCH_SHAPES:
        emit(
            f"linesearch_{rows}x{cols}",
            "linesearch",
            rows,
            cols,
            lower_linesearch(rows, cols),
        )

    with open(os.path.join(args.out, "manifest.toml"), "w") as f:
        f.write("\n".join(manifest_lines))
    print(f"wrote {args.out}/manifest.toml ({len(manifest_lines)} artifacts)")


if __name__ == "__main__":
    main()
