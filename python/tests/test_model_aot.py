"""L2 model functions + AOT lowering sanity.

Checks that every function `aot.py` ships (a) computes the right thing
and (b) lowers to parseable HLO text containing the expected parameter
shapes — the contract the rust runtime depends on.
"""

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def test_quad_grad_model_matches_ref():
    rng = np.random.default_rng(11)
    sx = rng.standard_normal((64, 32)).astype(np.float32)
    sy = rng.standard_normal(64).astype(np.float32)
    w = rng.standard_normal(32).astype(np.float32)
    (got,) = model.quad_grad(jnp.array(sx), jnp.array(sy), jnp.array(w))
    want = sx.T @ (sx @ w - sy)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_linesearch_model_matches_ref():
    rng = np.random.default_rng(13)
    sx = rng.standard_normal((32, 8)).astype(np.float32)
    d = rng.standard_normal(8).astype(np.float32)
    (got,) = model.linesearch_quad(jnp.array(sx), jnp.array(d))
    want = float(np.dot(sx @ d, sx @ d))
    assert abs(float(got) - want) < 1e-3 * max(1.0, want)


def test_prox_step_matches_soft_threshold():
    w = jnp.array([1.0, -2.0, 0.1, 0.0], jnp.float32)
    g = jnp.array([0.0, 0.0, 0.0, 1.0], jnp.float32)
    (out,) = model.prox_step(w, g, jnp.float32(0.5), jnp.float32(0.3))
    want = ref.soft_threshold_ref(w - 0.5 * g, 0.3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


def test_lowered_hlo_text_structure():
    text = aot.lower_quad_grad(64, 32, use_pallas=True)
    assert "HloModule" in text
    assert "f32[64,32]" in text  # sx parameter
    assert "f32[32]" in text  # w parameter / output
    # return_tuple=True → entry computation returns a 1-tuple
    assert "->(f32[32]" in text


def test_pallas_and_jnp_lowerings_agree_numerically():
    rng = np.random.default_rng(17)
    sx = rng.standard_normal((64, 32)).astype(np.float32)
    sy = rng.standard_normal(64).astype(np.float32)
    w = rng.standard_normal(32).astype(np.float32)
    (a,) = jax.jit(model.quad_grad)(sx, sy, w)
    (b,) = jax.jit(model.quad_grad_jnp)(sx, sy, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_linesearch_lowering_has_scalar_output():
    text = aot.lower_linesearch(128, 64)
    assert "HloModule" in text
    assert "f32[128,64]" in text
