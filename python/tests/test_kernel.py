"""L1 correctness: Pallas kernel vs pure-jnp oracle.

Hypothesis sweeps shapes and block sizes; fixed-seed numpy cases cover
the exact shard shapes the AOT pipeline ships.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.encoded_grad import (
    DEFAULT_BLOCK_ROWS,
    _pick_block_rows,
    encoded_grad,
    vmem_estimate_bytes,
)
from compile.kernels import ref


def random_case(rows, cols, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    sx = rng.standard_normal((rows, cols)).astype(dtype)
    sy = rng.standard_normal(rows).astype(dtype)
    w = rng.standard_normal(cols).astype(dtype)
    return sx, sy, w


@pytest.mark.parametrize("rows,cols", [(64, 32), (128, 64), (256, 64), (256, 128), (512, 128)])
def test_kernel_matches_ref_on_shipped_shapes(rows, cols):
    sx, sy, w = random_case(rows, cols, seed=rows * 1000 + cols)
    got = np.asarray(encoded_grad(jnp.array(sx), jnp.array(sy), jnp.array(w)))
    want = np.asarray(ref.encoded_grad_ref(jnp.array(sx), jnp.array(sy), jnp.array(w)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=96),
    cols=st.integers(min_value=1, max_value=48),
    block=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_kernel_matches_ref_hypothesis(rows, cols, block, seed):
    sx, sy, w = random_case(rows, cols, seed)
    got = np.asarray(
        encoded_grad(jnp.array(sx), jnp.array(sy), jnp.array(w), block_rows=block)
    )
    want = sx.T @ (sx @ w - sy)
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(min_value=2, max_value=64),
    cols=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_kernel_float64_path(rows, cols, seed):
    # interpret-mode kernel must respect the input dtype
    sx, sy, w = random_case(rows, cols, seed, dtype=np.float64)
    got = np.asarray(encoded_grad(jnp.array(sx), jnp.array(sy), jnp.array(w)))
    want = sx.T @ (sx @ w - sy)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


def test_zero_w_gives_minus_xty():
    sx, sy, _ = random_case(32, 8, 7)
    w = np.zeros(8, np.float32)
    got = np.asarray(encoded_grad(jnp.array(sx), jnp.array(sy), jnp.array(w)))
    np.testing.assert_allclose(got, -sx.T @ sy, rtol=1e-5, atol=1e-5)


def test_gradient_of_exact_fit_is_zero():
    rng = np.random.default_rng(3)
    sx = rng.standard_normal((40, 10)).astype(np.float32)
    w = rng.standard_normal(10).astype(np.float32)
    sy = (sx @ w).astype(np.float32)
    got = np.asarray(encoded_grad(jnp.array(sx), jnp.array(sy), jnp.array(w)))
    np.testing.assert_allclose(got, np.zeros(10), atol=1e-3)


@pytest.mark.parametrize("rows,requested,expect", [(128, 128, 128), (128, 100, 64), (7, 4, 1), (60, 16, 15)])
def test_pick_block_rows_divides(rows, requested, expect):
    b = _pick_block_rows(rows, requested)
    assert b == expect
    assert rows % b == 0


def test_vmem_estimate_is_positive_and_scales():
    small = vmem_estimate_bytes(32, 64)
    big = vmem_estimate_bytes(DEFAULT_BLOCK_ROWS, 64)
    assert 0 < small < big
