"""Test configuration: enable x64 so dtype-fidelity tests can use f64.

The shipped artifacts are all f32 (explicit ShapeDtypeStructs in
aot.py), so this does not change the lowering contract.
"""

import jax

jax.config.update("jax_enable_x64", True)
